//! Class-1b (DRAM-latency-bound) families: low MPKI — the memory rate is
//! throttled by computation between accesses — but LFMR ≈ 1, so every
//! access that does happen pays the full DRAM round trip, which lands on
//! the critical path.
//!
//! * [`RandomRmw`] — Chai `Histogram`-style: compute a bin (tens of
//!   instructions), then RMW a random slot of a DRAM-sized table.
//! * [`PointerChase`] — linked-structure walk (the paper's `PLYalu` /
//!   hardware-effects dependent chain): each load's *address* depends on
//!   the previous load, so no MLP exists at any core width.

use super::{chunks, layout, Scale};
use crate::sim::{Access, Trace};
use crate::util::rng::{mix64, Xoshiro256};

// (mix64 is used by RandomRmw's deterministic slot hashing.)

#[derive(Debug, Clone)]
pub struct RandomRmw {
    /// Table elements (16 B each).
    pub table_elems: usize,
    /// Total updates.
    pub updates: usize,
    /// Instructions of computation per update (keeps MPKI low).
    pub gap: u16,
    /// Arithmetic ops attributed per update.
    pub ops: u16,
    pub seed: u64,
}

impl RandomRmw {
    pub fn trace(&self, threads: usize, scale: Scale) -> Trace {
        let table = scale.n(self.table_elems, 8192);
        let updates = scale.n(self.updates, 2048);
        let input = layout::SHARED_BASE;
        let bins = layout::SHARED_BASE + (2u64 << 30);
        chunks(updates, threads)
            .into_iter()
            .map(|(start, len)| {
                let mut t = Vec::with_capacity(len * 3);
                for i in start..start + len {
                    // Sequential input scan (pixels/records) — L1-friendly.
                    t.push(Access::load(input + i as u64 * 8, self.gap / 2, self.ops / 2).in_bb(1));
                    let slot = mix64(i as u64 ^ self.seed) % table as u64;
                    let addr = bins + slot * 16;
                    // Read the bucket header word, write the payload word
                    // (same cache line, distinct words — the update has no
                    // word-level repeat, matching the paper's low temporal
                    // locality for this class).
                    t.push(Access::load(addr, self.gap / 2, self.ops / 2).in_bb(2));
                    t.push(Access::store(addr + 8, 1, 1).in_bb(2));
                }
                t
            })
            .collect()
    }
}

#[derive(Debug, Clone)]
pub struct PointerChase {
    /// Nodes in the linked structure (64 B apart — one per line).
    pub nodes: usize,
    /// Total hops walked.
    pub hops: usize,
    /// Instructions between hops.
    pub gap: u16,
    pub ops: u16,
    pub seed: u64,
}

impl PointerChase {
    pub fn trace(&self, threads: usize, scale: Scale) -> Trace {
        let nodes = scale.n(self.nodes, 8192);
        let hops = scale.n(self.hops, 2048);
        chunks(hops, threads)
            .into_iter()
            .enumerate()
            .map(|(tid, (_, len))| {
                // Each thread walks its own pseudo-random cycle through a
                // private region (threads do not share the structure —
                // matches pointer-chasing microbenchmarks).
                let base = layout::private_base(tid);
                let mut rng = Xoshiro256::new(self.seed ^ tid as u64);
                let mut t = Vec::with_capacity(len);
                for _ in 0..len {
                    // A fresh uniform node per hop models a walk over a
                    // full-cycle random permutation (no short cycles) —
                    // `dep` still serializes the loads in the core model.
                    let cur = rng.gen_range(nodes as u64);
                    t.push(Access::load_dep(base + cur * 64, self.gap, self.ops).in_bb(1));
                }
                t
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate, CoreModel, SystemConfig};

    #[test]
    fn random_rmw_is_1b_shaped() {
        let k = RandomRmw {
            table_elems: 1 << 22, // 64 MiB
            updates: 40_000,
            gap: 120,
            ops: 4,
            seed: 5,
        };
        let host = simulate(
            &SystemConfig::host(4, CoreModel::OutOfOrder),
            &k.trace(4, Scale(1.0)),
        );
        assert!(host.mpki < 11.0, "mpki={}", host.mpki);
        assert!(host.lfmr > 0.7, "lfmr={}", host.lfmr);
        assert!(host.dram_rho < 0.6, "rho={}", host.dram_rho);
        // NDP wins on latency (paper: 1.1-1.2x).
        let ndp = simulate(
            &SystemConfig::ndp(4, CoreModel::OutOfOrder),
            &k.trace(4, Scale(1.0)),
        );
        assert!(ndp.perf() > host.perf());
    }

    #[test]
    fn chase_is_fully_dependent() {
        let k = PointerChase {
            nodes: 1 << 20,
            hops: 20_000,
            gap: 10,
            ops: 2,
            seed: 1,
        };
        let t = k.trace(2, Scale(1.0));
        assert!(t[0].iter().all(|a| a.dep && !a.write));
        let host = simulate(&SystemConfig::host(2, CoreModel::OutOfOrder), &t);
        // AMAT dominated by DRAM.
        assert!(host.amat_parts[3] > host.amat_parts[0]);
        assert!(host.memory_bound > 0.6, "mb={}", host.memory_bound);
    }

    #[test]
    fn ndp_cuts_chase_amat() {
        let k = PointerChase {
            nodes: 1 << 20,
            hops: 20_000,
            gap: 10,
            ops: 2,
            seed: 1,
        };
        let host = simulate(
            &SystemConfig::host(2, CoreModel::OutOfOrder),
            &k.trace(2, Scale(1.0)),
        );
        let ndp = simulate(
            &SystemConfig::ndp(2, CoreModel::OutOfOrder),
            &k.trace(2, Scale(1.0)),
        );
        assert!(ndp.amat < host.amat, "ndp={} host={}", ndp.amat, host.amat);
        assert!(ndp.perf() > host.perf());
    }

    #[test]
    fn deterministic() {
        let k = PointerChase {
            nodes: 4096,
            hops: 5000,
            gap: 5,
            ops: 1,
            seed: 2,
        };
        assert_eq!(k.trace(3, Scale(1.0)), k.trace(3, Scale(1.0)));
    }
}
