//! Stencil sweeps (SPLASH-2 Ocean `relax`, Parboil `stencil`): 5-point
//! Jacobi iterations over a DRAM-sized 2-D grid. Rows stream
//! sequentially; the ±width accesses hit lines brought in one row ago —
//! reuse that L1 cannot hold once three rows exceed 32 KiB, making the
//! kernel stream from DRAM at scale (class 1a regular, like STREAM but
//! with a second "far" stride that defeats naive locality).

use super::{chunks, layout, Scale};
use crate::sim::{Access, Trace};

#[derive(Debug, Clone)]
pub struct Stencil {
    /// Grid width and height (elements).
    pub width: usize,
    pub height: usize,
    /// Sweeps over the grid.
    pub passes: usize,
}

impl Stencil {
    pub fn trace(&self, threads: usize, scale: Scale) -> Trace {
        let w = scale.n(self.width, 64);
        let h = scale.n(self.height, 8);
        let src = layout::SHARED_BASE;
        let dst = src + (w * h) as u64 * 8;
        // Parallelize over rows; each pass re-partitions identically.
        chunks(h, threads)
            .into_iter()
            .map(|(row0, rows)| {
                let mut t = Vec::with_capacity(rows * w * self.passes / 2);
                for _pass in 0..self.passes {
                    for r in row0..row0 + rows {
                        // Word-granularity would blow the trace up; emit one
                        // access per 4 elements (still inside-line samples
                        // preserved via the +1 word touch below).
                        for c in (0..w).step_by(4) {
                            let idx = |rr: usize, cc: usize| ((rr * w + cc) as u64) * 8;
                            t.push(Access::load(src + idx(r, c), 0, 1).in_bb(1));
                            t.push(Access::load(src + idx(r, (c + 1) % w), 0, 1).in_bb(1));
                            let up = if r == 0 { h - 1 } else { r - 1 };
                            let dn = if r + 1 == h { 0 } else { r + 1 };
                            t.push(Access::load(src + idx(up, c), 0, 1).in_bb(2));
                            t.push(Access::load(src + idx(dn, c), 0, 1).in_bb(2));
                            t.push(Access::store(dst + idx(r, c), 2, 4).in_bb(3));
                        }
                    }
                }
                t
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate, CoreModel, SystemConfig};

    #[test]
    fn large_grid_is_bandwidth_bound() {
        let s = Stencil {
            width: 2048,
            height: 256, // 4 MiB src; 3 rows = 48 KiB > L1
            passes: 1,
        };
        let r = simulate(
            &SystemConfig::host(4, CoreModel::OutOfOrder),
            &s.trace(4, Scale(1.0)),
        );
        assert!(r.mpki > 5.0, "mpki={}", r.mpki);
    }

    #[test]
    fn row_reuse_hits_cache_on_small_grid() {
        let s = Stencil {
            width: 256, // 3 rows = 6 KiB: fits L1
            height: 64,
            passes: 2,
        };
        let r = simulate(
            &SystemConfig::host(1, CoreModel::OutOfOrder),
            &s.trace(1, Scale(1.0)),
        );
        let hit_rate = r.l1_hits as f64 / (r.l1_hits + r.l1_misses) as f64;
        assert!(hit_rate > 0.7, "hit_rate={hit_rate}");
    }

    #[test]
    fn deterministic_strong_scaling() {
        let s = Stencil {
            width: 512,
            height: 64,
            passes: 1,
        };
        let n1: usize = s.trace(1, Scale(1.0)).iter().map(Vec::len).sum();
        let n8: usize = s.trace(8, Scale(1.0)).iter().map(Vec::len).sum();
        assert_eq!(n1, n8);
        assert_eq!(s.trace(8, Scale(1.0)), s.trace(8, Scale(1.0)));
    }
}
