//! The DAMOV benchmark suite: deterministic trace generators reproducing
//! the memory access patterns of the paper's representative functions.
//!
//! Each *function* (paper terminology: a memory-bound function inside an
//! application) is described by a [`FunctionSpec`]: identity (suite /
//! application / function / input set, mirroring Appendix A), the paper's
//! bottleneck-class label for the 44 representatives, and a [`Kernel`] —
//! a parametric access-pattern generator. Generators:
//!
//! * are **deterministic** (seeded xoshiro256**) — the same spec always
//!   yields the same trace;
//! * **strong-scale**: total work is fixed and partitioned across the
//!   simulated cores, as in the paper's scalability sweep;
//! * emit **word-granularity** accesses so the architecture-independent
//!   locality metrics of Step 2 (computed at word granularity, §2.3) see
//!   the true access stream;
//! * tag accesses with static basic-block ids (`Access::bb`) so case
//!   study 4 can attribute LLC misses to basic blocks.
//!
//! See DESIGN.md §4 for the mapping from each paper function to its
//! generator family and the argument for pattern fidelity.

pub mod compute;
pub mod contention;
pub mod graph;
pub mod hashjoin;
pub mod l1bound;
pub mod latency;
pub mod partition;
pub mod registry;
pub mod stencil;
pub mod stream;

use crate::sim::Trace;

/// Global size multiplier. `Scale(1.0)` is the evaluation scale used for
/// the paper reproduction; tests use small scales for speed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Scale(pub f64);

impl Scale {
    pub fn full() -> Scale {
        Scale(1.0)
    }

    pub fn tiny() -> Scale {
        Scale(0.05)
    }

    /// Scale an element/byte count, keeping it at least `min`.
    pub fn n(&self, base: usize, min: usize) -> usize {
        ((base as f64 * self.0) as usize).max(min)
    }
}

/// Identity of a benchmark function (Appendix A columns).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct FunctionId {
    pub suite: &'static str,
    pub app: &'static str,
    pub function: &'static str,
    /// Input set tag (e.g. "rMat", "USA", "ref", "small").
    pub input: String,
}

impl FunctionId {
    /// Short code used throughout the paper's figures (e.g. `LIGPrkEmd`).
    pub fn code(&self) -> String {
        format!("{}{}", self.app, self.function)
    }
}

/// A function in the suite: identity + expected class + generator.
#[derive(Debug, Clone)]
pub struct FunctionSpec {
    pub id: FunctionId,
    /// Paper bottleneck class ("1a".."2c") for the 44 representatives;
    /// `None` for held-out validation variants (their class is predicted
    /// by the classifier and then checked against the family's label).
    pub paper_class: Option<&'static str>,
    /// The class of the generator *family* (ground truth for validation).
    pub family_class: &'static str,
    pub kernel: Kernel,
    /// True for the 44 representative functions (Table 8).
    pub representative: bool,
}

impl FunctionSpec {
    /// Generate the multi-threaded trace for `threads` cores.
    pub fn trace(&self, threads: usize, scale: Scale) -> Trace {
        self.kernel.trace(threads, scale)
    }

    /// Single-thread trace for the architecture-independent Step-2
    /// locality analysis (paper: single-thread memory trace).
    pub fn locality_trace(&self, scale: Scale) -> Vec<crate::sim::Access> {
        self.kernel.trace(1, scale).pop().unwrap()
    }
}

/// Parametric generator families (DESIGN.md §4). Every paper function is
/// an instance of one of these with specific sizes/rates.
#[derive(Debug, Clone)]
pub enum Kernel {
    /// STREAM-style array sweeps (1a regular).
    Stream(stream::StreamKernel),
    /// Streaming GEMM with negligible reuse (1a regular, DRKYolo).
    GemmStream(stream::GemmStream),
    /// Hash-join probe: sequential keys + random table reads (1a irregular).
    HashProbe(hashjoin::HashProbe),
    /// Hash-join build: random RMW at low rate (1b).
    HashBuild(hashjoin::HashBuild),
    /// Graph traversal over rMat or grid graphs (1a irregular).
    Graph(graph::GraphTraversal),
    /// Jacobi-style stencil sweeps (1a regular).
    Stencil(stencil::Stencil),
    /// Sparse random RMW over a huge table, compute-heavy gaps (1b).
    RandomRmw(latency::RandomRmw),
    /// Dependent pointer chase (1b).
    PointerChase(latency::PointerChase),
    /// Repeated passes over per-thread partitions (1c).
    PartitionedPass(partition::PartitionedPass),
    /// Hot per-thread block with RMW reuse; aggregate overwhelms L3 at
    /// high core counts (2a).
    SharedHotRmw(contention::SharedHotRmw),
    /// Hot L1-resident vectors + shared L3-resident matrix stream (2b).
    StreamPlusHot(l1bound::StreamPlusHot),
    /// Cache-blocked high-AI compute (2c).
    BlockedCompute(compute::BlockedCompute),
}

impl Kernel {
    pub fn trace(&self, threads: usize, scale: Scale) -> Trace {
        match self {
            Kernel::Stream(k) => k.trace(threads, scale),
            Kernel::GemmStream(k) => k.trace(threads, scale),
            Kernel::HashProbe(k) => k.trace(threads, scale),
            Kernel::HashBuild(k) => k.trace(threads, scale),
            Kernel::Graph(k) => k.trace(threads, scale),
            Kernel::Stencil(k) => k.trace(threads, scale),
            Kernel::RandomRmw(k) => k.trace(threads, scale),
            Kernel::PointerChase(k) => k.trace(threads, scale),
            Kernel::PartitionedPass(k) => k.trace(threads, scale),
            Kernel::SharedHotRmw(k) => k.trace(threads, scale),
            Kernel::StreamPlusHot(k) => k.trace(threads, scale),
            Kernel::BlockedCompute(k) => k.trace(threads, scale),
        }
    }

    /// Dataflow summary for the accelerator case study (§5.2), where
    /// meaningful for the family.
    pub fn dataflow(&self) -> Option<crate::sim::accel::KernelDataflow> {
        use crate::sim::accel::KernelDataflow;
        match self {
            Kernel::GemmStream(k) => Some(KernelDataflow {
                // Per 8-word block of the B sweep: one B line + one C
                // update (16 B amortized), ~1.2 ops after the MAC tree
                // folds into the accelerator datapath.
                ops_per_elem: 1.2,
                chain_depth: 8.0,
                bytes_per_elem: 16.0,
                elems: (k.m * k.n * k.k) as f64 / 8.0,
                latency_bound_frac: 0.0,
            }),
            Kernel::RandomRmw(k) => Some(KernelDataflow {
                ops_per_elem: k.ops as f64 + 2.0,
                chain_depth: 4.0,
                bytes_per_elem: 16.0,
                elems: k.updates as f64,
                latency_bound_frac: 0.7,
            }),
            Kernel::PointerChase(k) => Some(KernelDataflow {
                ops_per_elem: k.ops as f64 + 2.0,
                chain_depth: 2.0,
                bytes_per_elem: 8.0,
                elems: k.hops as f64,
                latency_bound_frac: 0.5,
            }),
            Kernel::BlockedCompute(k) => Some(KernelDataflow {
                ops_per_elem: k.ops as f64,
                chain_depth: 8.0,
                bytes_per_elem: 0.5,
                elems: k.iters as f64,
                latency_bound_frac: 0.0,
            }),
            _ => None,
        }
    }
}

/// Memory-layout constants shared by all generators: private regions are
/// spaced far apart; shared structures live in a common arena.
pub mod layout {
    /// Base of the shared arena (graph data, shared matrices...).
    pub const SHARED_BASE: u64 = 0x1000_0000;
    /// Base of thread-private arenas.
    pub const PRIVATE_BASE: u64 = 0x10_0000_0000;
    /// Stride between thread-private arenas (256 MiB).
    pub const PRIVATE_STRIDE: u64 = 0x1000_0000;

    pub fn private_base(thread: usize) -> u64 {
        PRIVATE_BASE + thread as u64 * PRIVATE_STRIDE
    }
}

/// Split `total` units of work into per-thread (start, len) chunks.
pub fn chunks(total: usize, threads: usize) -> Vec<(usize, usize)> {
    let threads = threads.max(1);
    let per = total / threads;
    let rem = total % threads;
    let mut out = Vec::with_capacity(threads);
    let mut start = 0;
    for t in 0..threads {
        let len = per + usize::from(t < rem);
        out.push((start, len));
        start += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_everything() {
        crate::util::prop::check(100, |rng| {
            let total = rng.gen_usize(0, 10_000);
            let threads = rng.gen_usize(1, 300);
            let ch = chunks(total, threads);
            assert_eq!(ch.len(), threads);
            let sum: usize = ch.iter().map(|c| c.1).sum();
            assert_eq!(sum, total);
            // Contiguous and ordered.
            let mut pos = 0;
            for (s, l) in ch {
                assert_eq!(s, pos);
                pos += l;
            }
        });
    }

    #[test]
    fn scale_respects_min() {
        assert_eq!(Scale(0.001).n(1000, 64), 64);
        assert_eq!(Scale(2.0).n(1000, 64), 2000);
    }

    #[test]
    fn private_bases_disjoint() {
        let a = layout::private_base(0);
        let b = layout::private_base(1);
        assert!(b - a >= layout::PRIVATE_STRIDE);
        assert!(a > layout::SHARED_BASE + (1 << 30));
    }
}
