//! Class-2b family: **L1-capacity-bound** (PLYgemver, PLYmvt, PLYbicg,
//! SPLLucb).
//!
//! Pattern (paper §3.3.5): a hot, L1-resident vector block is re-read
//! constantly (high temporal locality) while a shared, L3-resident
//! matrix streams through — the minority of accesses that miss L1 hit
//! the L3 on the host, or DRAM on NDP, and the two latencies roughly
//! cancel: host and NDP perform within a few percent of each other at
//! every core count, with low MPKI and low/medium constant LFMR.

use super::{chunks, layout, Scale};
use crate::sim::{Access, Trace};

#[derive(Debug, Clone)]
pub struct StreamPlusHot {
    /// DRAM-resident streamed operand, in words (> LLC — the "A matrix").
    /// Misses here reach DRAM on both host and NDP, which is what makes
    /// the two systems perform on par.
    pub big_words: usize,
    /// LLC-resident operand, in words (≤ L3; its L1 misses hit L3 on the
    /// host but DRAM on NDP — roughly cancelling the link latency the
    /// host pays on the big stream). Together: LFMR ≈ 0.5, constant.
    pub med_words: usize,
    /// Hot per-thread vector block in words (j-block; L1-resident;
    /// re-read every iteration — the temporal-locality signal).
    pub hot_words: usize,
    /// Fraction (x1000) of blocks that RMW the accumulator word.
    pub rmw_per_mille: usize,
    /// Instruction gap on the streamed loads (rate-limits MPKI).
    pub gap: u16,
}

impl StreamPlusHot {
    pub fn trace(&self, threads: usize, scale: Scale) -> Trace {
        let big = scale.n(self.big_words, 128 * 1024);
        let med = scale.n(self.med_words, 32 * 1024);
        let hot = self.hot_words.clamp(8, 1024);
        let a_base = layout::SHARED_BASE;
        let b_base = a_base + big as u64 * 8;
        let total_blocks = big / hot;
        chunks(total_blocks, threads)
            .into_iter()
            .enumerate()
            .map(|(tid, (start, my_blocks))| {
                let x_base = layout::private_base(tid);
                let y_base = x_base + (1 << 20);
                let mut t = Vec::with_capacity(my_blocks * (hot * 3 + 4));
                // Per j-block: stream `hot` A-words (DRAM-resident) and
                // `hot` B-words (LLC-resident), re-reading the same `hot`
                // x-words twice (reuse distance < 32 refs — the Step-2
                // temporal signal), plus an occasional y accumulator RMW.
                // Each thread re-sweeps its own slice of the cache-warm B
                // operand (kept above L1 size so its accesses still miss
                // L1 — they hit L3/L2 on the host but DRAM on NDP, which
                // is the latency-cancellation that puts the two systems
                // on par; paper §3.3.5).
                let b_slice = (med / threads.max(1)).max(6 * 1024);
                let b_slice_base = b_base + ((tid * b_slice) % med) as u64 * 8;
                let bpass = (b_slice / hot).max(1);
                for bi in start..start + my_blocks {
                    let arow = a_base + ((bi % total_blocks) * hot) as u64 * 8;
                    let brow = b_slice_base + ((bi % bpass) * hot) as u64 * 8;
                    for j in 0..hot {
                        t.push(Access::load(arow + j as u64 * 8, self.gap, 0).in_bb(1));
                        t.push(Access::load(x_base + j as u64 * 8, 0, 1).in_bb(2));
                        t.push(Access::load(brow + j as u64 * 8, self.gap, 0).in_bb(3));
                        t.push(Access::load(x_base + j as u64 * 8, 0, 1).in_bb(2));
                    }
                    if (bi * 1000 / total_blocks.max(1)) % 1000 < self.rmw_per_mille {
                        let y = y_base + (bi % 64) as u64 * 8;
                        t.push(Access::load(y, 0, 0).in_bb(4));
                        t.push(Access::store(y, 1, 1).in_bb(4));
                    }
                }
                t
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate, CoreModel, SystemConfig};

    fn kernel() -> StreamPlusHot {
        StreamPlusHot {
            big_words: 2 << 20,  // 16 MiB: exceeds the 8 MiB LLC
            med_words: 256 * 1024, // 2 MiB: LLC-resident
            hot_words: 8,
            rmw_per_mille: 250,
            gap: 5,
        }
    }

    #[test]
    fn host_and_ndp_on_par() {
        let k = kernel();
        for cores in [1usize, 16] {
            let host = simulate(
                &SystemConfig::host(cores, CoreModel::OutOfOrder),
                &k.trace(cores, Scale(1.0)),
            );
            let ndp = simulate(
                &SystemConfig::ndp(cores, CoreModel::OutOfOrder),
                &k.trace(cores, Scale(1.0)),
            );
            let ratio = ndp.perf() / host.perf();
            assert!(
                (0.75..1.35).contains(&ratio),
                "cores={cores}: ndp/host={ratio}"
            );
        }
    }

    #[test]
    fn low_mpki_and_bounded_lfmr() {
        let k = kernel();
        let r = simulate(
            &SystemConfig::host(4, CoreModel::OutOfOrder),
            &k.trace(4, Scale(1.0)),
        );
        assert!(r.mpki < 11.0, "mpki={}", r.mpki);
        assert!(r.lfmr < 0.75, "lfmr={}", r.lfmr);
        // Most loads are L1 hits (hot vector).
        assert!(r.level_fracs[0] > 0.5, "l1 frac={}", r.level_fracs[0]);
    }

    #[test]
    fn lfmr_roughly_constant_across_cores() {
        let k = kernel();
        let lfmr_at = |cores: usize| {
            simulate(
                &SystemConfig::host(cores, CoreModel::OutOfOrder),
                &k.trace(cores, Scale(1.0)),
            )
            .lfmr
        };
        let a = lfmr_at(1);
        let b = lfmr_at(64);
        assert!((a - b).abs() < 0.35, "1c={a} 64c={b}");
    }

    #[test]
    fn deterministic() {
        let k = kernel();
        assert_eq!(k.trace(2, Scale(0.2)), k.trace(2, Scale(0.2)));
    }
}
