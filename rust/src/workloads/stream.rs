//! Class-1a regular-streaming families.
//!
//! * [`StreamKernel`] — the STREAM micro-benchmarks (McCalpin): Copy
//!   (`a[i]=b[i]`), Scale (`a[i]=s*b[i]`), Add (`a[i]=b[i]+c[i]`), Triad
//!   (`a[i]=b[i]+s*c[i]`). Pure sequential sweeps over DRAM-sized arrays:
//!   the canonical DRAM-bandwidth-bound pattern (high MPKI, LFMR ≈ 1,
//!   low temporal locality, spatial locality ≈ 1, AI ≤ a few ops/line).
//! * [`GemmStream`] — Darknet's Yolo `gemm` on large layers: naive
//!   row-major GEMM whose B-matrix column sweep has no reuse at this
//!   cache size, making it a (regular) bandwidth-bound stream with a bit
//!   more arithmetic.

use super::{chunks, layout, Scale};
use crate::sim::{Access, Trace};

/// Which STREAM kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamOp {
    Copy,
    Scale,
    Add,
    Triad,
}

#[derive(Debug, Clone)]
pub struct StreamKernel {
    pub op: StreamOp,
    /// Elements per array (f64 words).
    pub elems: usize,
}

impl StreamKernel {
    pub fn new(op: StreamOp, elems: usize) -> StreamKernel {
        StreamKernel { op, elems }
    }

    pub fn trace(&self, threads: usize, scale: Scale) -> Trace {
        let elems = scale.n(self.elems, 1024);
        // Arrays a (dst), b, c live in the shared arena back to back.
        let a = layout::SHARED_BASE;
        let b = a + (elems as u64) * 8;
        let c = b + (elems as u64) * 8;
        chunks(elems, threads)
            .into_iter()
            .map(|(start, len)| {
                let mut t = Vec::with_capacity(len * 3);
                for i in start..start + len {
                    let off = i as u64 * 8;
                    match self.op {
                        StreamOp::Copy => {
                            t.push(Access::load(b + off, 0, 0).in_bb(1));
                            t.push(Access::store(a + off, 0, 0).in_bb(1));
                        }
                        StreamOp::Scale => {
                            t.push(Access::load(b + off, 0, 0).in_bb(1));
                            t.push(Access::store(a + off, 1, 1).in_bb(1));
                        }
                        StreamOp::Add => {
                            t.push(Access::load(b + off, 0, 0).in_bb(1));
                            t.push(Access::load(c + off, 0, 0).in_bb(1));
                            t.push(Access::store(a + off, 1, 1).in_bb(1));
                        }
                        StreamOp::Triad => {
                            t.push(Access::load(b + off, 0, 0).in_bb(1));
                            t.push(Access::load(c + off, 0, 0).in_bb(1));
                            t.push(Access::store(a + off, 1, 2).in_bb(1));
                        }
                    }
                }
                t
            })
            .collect()
    }
}

/// Streaming GEMM: C[m,n] += A[m,k]*B[k,n], row-major, no blocking.
/// For each output row, A's row streams once while B streams entirely —
/// B (k×n doubles) far exceeds the LLC, so the access stream is a long
/// sequential sweep repeated `m` times (zero inter-sweep reuse at the
/// paper's sizes), with 2 flops per element.
#[derive(Debug, Clone)]
pub struct GemmStream {
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl GemmStream {
    pub fn trace(&self, threads: usize, scale: Scale) -> Trace {
        let m = scale.n(self.m, 2);
        let n = scale.n(self.n, 64);
        let k = scale.n(self.k, 8);
        let a_base = layout::SHARED_BASE;
        let b_base = a_base + (m * k) as u64 * 8;
        let c_base = b_base + (k * n) as u64 * 8;
        // Parallelize over (output row, column block) work items so the
        // trace strong-scales past m threads; B stays shared, and a
        // thread's B column-slice still exceeds the private caches at
        // every paper core count.
        let jb = 512usize.min(n); // words per column block
        let blocks_per_row = n / jb;
        let items = m * blocks_per_row;
        chunks(items, threads)
            .into_iter()
            .map(|(item0, n_items)| {
                let mut t = Vec::with_capacity(n_items * k * (jb / 8 + 1) * 2);
                for item in item0..item0 + n_items {
                    let i = item % m;
                    let jb0 = (item / m) * jb;
                    for kk in 0..k {
                        // a[i][kk] — reused across the j loop; hot.
                        t.push(Access::load(a_base + (i * k + kk) as u64 * 8, 1, 0).in_bb(1));
                        // Stream B row kk over this column block and
                        // update C row i (one representative word per
                        // line, ops for 8 MACs).
                        for j in (jb0..jb0 + jb).step_by(8) {
                            t.push(
                                Access::load(b_base + (kk * n + j) as u64 * 8, 1, 8).in_bb(2),
                            );
                            t.push(Access::store(c_base + (i * n + j) as u64 * 8, 1, 8).in_bb(2));
                        }
                    }
                }
                t
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate, CoreModel, SystemConfig};

    #[test]
    fn triad_has_three_accesses_per_element() {
        let k = StreamKernel::new(StreamOp::Triad, 2048);
        let t = k.trace(1, Scale(1.0));
        assert_eq!(t[0].len(), 3 * 2048);
    }

    #[test]
    fn work_is_strong_scaled() {
        let k = StreamKernel::new(StreamOp::Add, 10_000);
        let t1 = k.trace(1, Scale(1.0));
        let t4 = k.trace(4, Scale(1.0));
        let n1: usize = t1.iter().map(Vec::len).sum();
        let n4: usize = t4.iter().map(Vec::len).sum();
        assert_eq!(n1, n4);
        assert_eq!(t4.len(), 4);
    }

    #[test]
    fn stream_is_class_1a_shaped() {
        // High MPKI, LFMR near 1 on the host config.
        let k = StreamKernel::new(StreamOp::Triad, 200_000);
        let cfg = SystemConfig::host(4, CoreModel::OutOfOrder);
        let r = simulate(&cfg, &k.trace(4, Scale(1.0)));
        assert!(r.mpki > 10.0, "mpki={}", r.mpki);
        assert!(r.lfmr > 0.7, "lfmr={}", r.lfmr);
        assert!(r.memory_bound > 0.3, "mb={}", r.memory_bound);
    }

    #[test]
    fn threads_partition_disjoint_ranges() {
        let k = StreamKernel::new(StreamOp::Copy, 10_000);
        let t = k.trace(2, Scale(1.0));
        let max0 = t[0].iter().map(|a| a.addr).max().unwrap();
        let min1 = t[1].iter().map(|a| a.addr).min().unwrap();
        // Thread 1's lowest b-array address is above thread 0's highest
        // a-array address only within the same array; check per-array by
        // filtering to loads of array b (lowest region is array a).
        assert!(min1 > 0);
        assert!(max0 > 0);
        // The essential property: deterministic.
        let t2 = k.trace(2, Scale(1.0));
        assert_eq!(t[0], t2[0]);
    }

    #[test]
    fn gemm_streams_b_matrix() {
        let g = GemmStream {
            m: 8,
            n: 512,
            k: 32,
        };
        let t = g.trace(2, Scale(1.0));
        let total: usize = t.iter().map(Vec::len).sum();
        assert!(total > 8 * 32 * 64, "total={total}");
        // Deterministic.
        assert_eq!(g.trace(2, Scale(1.0))[1], t[1]);
    }

    #[test]
    fn gemm_is_bandwidth_bound_at_scale() {
        let g = GemmStream {
            m: 16,
            n: 4096,
            k: 64,
        };
        let cfg = SystemConfig::host(4, CoreModel::OutOfOrder);
        let r = simulate(&cfg, &g.trace(4, Scale(1.0)));
        assert!(r.mpki > 10.0, "mpki={}", r.mpki);
    }
}
