//! Graph-processing kernels (the paper's Ligra functions, class 1a
//! irregular).
//!
//! The paper evaluates Ligra kernels on two inputs with very different
//! structure: `rMat` (power-law, scattered) and `USA` (road network:
//! near-planar grid, spatially local but with a huge working set). We
//! reproduce the *edgeMap access pattern* of those kernels:
//!
//! * **Dense** (`edgeMapDense`, e.g. PageRank / TriangleCount): iterate
//!   all destination vertices sequentially; for each, gather the values
//!   of its in-neighbors — sequential offset reads + per-edge scattered
//!   value reads. Gathers are independent → high MLP → DRAM
//!   bandwidth-bound once the value array exceeds the LLC.
//! * **Sparse** (`edgeMapSparse`, e.g. ConnectedComponents / Radii /
//!   KCore): iterate a scattered frontier; per edge, read the neighbor
//!   value and conditionally update it (RMW scatter).
//!
//! Neighbor ids are sampled deterministically: rMat endpoints are
//! Zipf-distributed then bit-mixed (power-law degree + scattered ids,
//! the two properties that matter for cache behavior); grid neighbors
//! are ±1/±width (road-network locality).

use super::{chunks, layout, Scale};
use crate::sim::{Access, Trace};
use crate::util::rng::{mix64, Xoshiro256};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GraphInput {
    RMat,
    /// Road-network-like 2-D grid.
    Usa,
}

impl GraphInput {
    pub fn tag(&self) -> &'static str {
        match self {
            GraphInput::RMat => "rMat",
            GraphInput::Usa => "USA",
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraversalMode {
    Dense,
    Sparse,
}

#[derive(Debug, Clone)]
pub struct GraphTraversal {
    pub input: GraphInput,
    pub mode: TraversalMode,
    /// Vertices.
    pub vertices: usize,
    /// Process every `visit_step`-th vertex (keeps the trace short while
    /// the value array stays DRAM-sized — the property that matters).
    pub visit_step: usize,
    /// Average degree.
    pub degree: usize,
    /// Bytes per vertex value (8 = one word, 16 = rank+delta, ...).
    pub value_words: usize,
    pub seed: u64,
}

impl GraphTraversal {
    fn neighbor(&self, v: usize, e: usize, nv: usize, rng: &mut Xoshiro256) -> u64 {
        match self.input {
            GraphInput::RMat => {
                // Power-law endpoint, scattered by a fixed permutation.
                let z = rng.gen_zipf(nv, 0.8);
                mix64(z as u64 ^ self.seed) % nv as u64
            }
            GraphInput::Usa => {
                // Grid: ±1, ±width with small jitter.
                let width = (nv as f64).sqrt() as i64;
                let delta = match e % 4 {
                    0 => 1,
                    1 => -1,
                    2 => width,
                    _ => -width,
                };
                ((v as i64 + delta).rem_euclid(nv as i64)) as u64
            }
        }
    }

    pub fn trace(&self, threads: usize, scale: Scale) -> Trace {
        let nv = scale.n(self.vertices, 4096);
        let step = self.visit_step.max(1);
        let visited = nv / step;
        let offsets = layout::SHARED_BASE;
        let values = offsets + nv as u64 * 8;
        let frontier = values + (nv * self.value_words) as u64 * 8;
        chunks(visited, threads)
            .into_iter()
            .enumerate()
            .map(|(tid, (start, len))| {
                let mut rng = Xoshiro256::new(self.seed ^ (tid as u64).wrapping_mul(0x9E37));
                let mut t = Vec::with_capacity(len * (self.degree + 2));
                for vi in start..start + len {
                    let v = (vi * step) % nv;
                    // Degree: power-law for rMat, ~4 for grid.
                    let deg = match self.input {
                        GraphInput::RMat => {
                            let d = rng.gen_zipf(4 * self.degree, 0.9) + 1;
                            d.min(4 * self.degree)
                        }
                        GraphInput::Usa => 4,
                    };
                    match self.mode {
                        TraversalMode::Dense => {
                            // Sequential offset read for this vertex.
                            t.push(Access::load(offsets + v as u64 * 8, 1, 1).in_bb(1));
                            for e in 0..deg {
                                let u = self.neighbor(v, e, nv, &mut rng);
                                // Gather neighbor value (independent).
                                t.push(
                                    Access::load(
                                        values + u * (self.value_words as u64) * 8,
                                        1,
                                        1,
                                    )
                                    .in_bb(2),
                                );
                            }
                            // Accumulate into own value (hot during loop).
                            t.push(
                                Access::store(
                                    values + v as u64 * (self.value_words as u64) * 8,
                                    1,
                                    2,
                                )
                                .in_bb(3),
                            );
                        }
                        TraversalMode::Sparse => {
                            // Scattered frontier read.
                            let fv = mix64(v as u64 ^ self.seed) % nv as u64;
                            t.push(Access::load(frontier + fv * 8, 1, 1).in_bb(1));
                            let next_frontier = frontier + nv as u64 * 8;
                            for e in 0..deg {
                                let u = self.neighbor(fv as usize, e, nv, &mut rng);
                                let va = values + u * (self.value_words as u64) * 8;
                                // Read the neighbor value; conditionally
                                // mark it in the next frontier (as Ligra's
                                // edgeMapSparse does) — a distinct array,
                                // so no word-level repeats.
                                t.push(Access::load(va, 1, 1).in_bb(2));
                                if e % 2 == 0 {
                                    t.push(Access::store(next_frontier + u * 8, 0, 1).in_bb(2));
                                }
                            }
                        }
                    }
                }
                t
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate, CoreModel, SystemConfig};

    fn pagerank_rmat() -> GraphTraversal {
        GraphTraversal {
            input: GraphInput::RMat,
            mode: TraversalMode::Dense,
            vertices: 1_600_000, // 12.8 MiB value array: exceeds the LLC
            visit_step: 4,
            degree: 4,
            value_words: 1,
            seed: 42,
        }
    }

    #[test]
    fn rmat_dense_is_class1a_irregular() {
        let g = pagerank_rmat();
        let r = simulate(
            &SystemConfig::host(4, CoreModel::OutOfOrder),
            &g.trace(4, Scale(1.0)),
        );
        assert!(r.mpki > 5.0, "mpki={}", r.mpki);
        assert!(r.lfmr > 0.3, "lfmr={}", r.lfmr);
    }

    /// Median |stride| between consecutive *gather* accesses (bb == 2).
    fn median_gather_stride(g: &GraphTraversal) -> u64 {
        let t = g.trace(1, Scale(1.0));
        let gathers: Vec<u64> = t[0].iter().filter(|a| a.bb == 2).map(|a| a.addr).collect();
        let mut ds: Vec<u64> = gathers.windows(2).map(|w| w[0].abs_diff(w[1])).collect();
        ds.sort_unstable();
        ds[ds.len() / 2]
    }

    #[test]
    fn usa_gathers_are_more_local_than_rmat() {
        let usa = GraphTraversal {
            input: GraphInput::Usa,
            mode: TraversalMode::Dense,
            vertices: 400_000,
            visit_step: 2,
            degree: 4,
            value_words: 1,
            seed: 1,
        };
        let usa_stride = median_gather_stride(&usa);
        let rmat_stride = median_gather_stride(&pagerank_rmat());
        assert!(
            usa_stride * 10 < rmat_stride,
            "usa={usa_stride} rmat={rmat_stride}"
        );
    }

    #[test]
    fn deterministic() {
        let g = pagerank_rmat();
        assert_eq!(g.trace(2, Scale(0.2)), g.trace(2, Scale(0.2)));
    }

    #[test]
    fn sparse_mode_has_rmw_stores() {
        let g = GraphTraversal {
            input: GraphInput::RMat,
            mode: TraversalMode::Sparse,
            vertices: 50_000,
            visit_step: 1,
            degree: 4,
            value_words: 1,
            seed: 9,
        };
        let t = g.trace(1, Scale(1.0));
        let stores = t[0].iter().filter(|a| a.write).count();
        assert!(stores > t[0].len() / 10);
    }

    #[test]
    fn power_law_degrees_for_rmat() {
        let g = pagerank_rmat();
        let t = g.trace(1, Scale(0.5));
        // bb=1 marks one offset read per vertex; bb=2 marks gathers. The
        // gather/vertex ratio should exceed the grid's uniform 4 spread
        // (power law has a heavy tail but median ~1-2); just check both
        // tags are present and gathers dominate.
        let offsets = t[0].iter().filter(|a| a.bb == 1).count();
        let gathers = t[0].iter().filter(|a| a.bb == 2).count();
        assert!(offsets > 0 && gathers > offsets);
    }
}
