//! The DAMOV function registry: the 44 representative functions of
//! Table 8 (by paper code name) and the 100 held-out input/size variants
//! that mirror the paper's §3.5 validation set, for 144 functions total.
//!
//! Representative functions carry their paper class; variants carry only
//! their generator family's class (used as ground truth when measuring
//! classification accuracy).

use super::compute::BlockedCompute;
use super::contention::SharedHotRmw;
use super::graph::{GraphInput, GraphTraversal, TraversalMode};
use super::hashjoin::{HashBuild, HashProbe};
use super::l1bound::StreamPlusHot;
use super::latency::{PointerChase, RandomRmw};
use super::partition::PartitionedPass;
use super::stencil::Stencil;
use super::stream::{GemmStream, StreamKernel, StreamOp};
use super::{FunctionId, FunctionSpec, Kernel};

fn spec(
    suite: &'static str,
    app: &'static str,
    function: &'static str,
    input: &str,
    class: &'static str,
    kernel: Kernel,
) -> FunctionSpec {
    FunctionSpec {
        id: FunctionId {
            suite,
            app,
            function,
            input: input.to_string(),
        },
        paper_class: Some(class),
        family_class: class,
        kernel,
        representative: true,
    }
}

fn graph(input: GraphInput, mode: TraversalMode, vertices: usize, seed: u64) -> Kernel {
    Kernel::Graph(GraphTraversal {
        input,
        mode,
        vertices,
        visit_step: 4,
        degree: 4,
        value_words: 1,
        seed,
    })
}

/// The 44 representative functions (Table 8). Codes match the paper's
/// figures (e.g. `LIGPrkEmd` = Ligra PageRank edgeMapDense).
pub fn representatives() -> Vec<FunctionSpec> {
    use GraphInput::*;
    use TraversalMode::*;
    let mut v = Vec::new();

    // ---- Class 1a: DRAM bandwidth-bound (12) ----
    for (name, op) in [
        ("Add", StreamOp::Add),
        ("Cpy", StreamOp::Copy),
        ("Sca", StreamOp::Scale),
        ("Triad", StreamOp::Triad),
    ] {
        v.push(spec(
            "STREAM",
            "STR",
            name,
            "50000000",
            "1a",
            Kernel::Stream(StreamKernel::new(op, 160_000)),
        ));
    }
    v.push(spec(
        "Darknet",
        "DRK",
        "Yolo",
        "ref",
        "1a",
        Kernel::GemmStream(GemmStream {
            // B (k x n doubles = 9.4 MiB) exceeds the 8 MiB LLC, so the
            // repeated B sweep streams from DRAM (the 1a invariant).
            m: 8,
            n: 24576,
            k: 48,
        }),
    ));
    v.push(spec(
        "Hashjoin",
        "HSJ",
        "NPO",
        "r12.8M-s12M",
        "1a",
        Kernel::HashProbe(HashProbe {
            table_elems: 1 << 20,
            probes: 150_000,
            gap: 2,
            seed: 12345,
        }),
    ));
    v.push(spec(
        "Ligra",
        "LIG",
        "CompEms",
        "USA",
        "1a",
        graph(Usa, Sparse, 1_600_000, 21),
    ));
    v.push(spec(
        "Ligra",
        "LIG",
        "PrkEmd",
        "USA",
        "1a",
        graph(Usa, Dense, 1_600_000, 22),
    ));
    v.push(spec(
        "Ligra",
        "LIG",
        "TriEmd",
        "rMat",
        "1a",
        graph(RMat, Dense, 1_600_000, 23),
    ));
    v.push(spec(
        "Ligra",
        "LIG",
        "RadiEms",
        "USA",
        "1a",
        graph(Usa, Sparse, 1_600_000, 24),
    ));
    v.push(spec(
        "Ligra",
        "LIG",
        "KcrEms",
        "rMat",
        "1a",
        graph(RMat, Sparse, 1_600_000, 25),
    ));
    v.push(spec(
        "SPLASH-2",
        "SPL",
        "OcpRelax",
        "simlarge",
        "1a",
        Kernel::Stencil(Stencil {
            width: 2048,
            height: 256,
            passes: 1,
        }),
    ));

    // ---- Class 1b: DRAM latency-bound (5) ----
    v.push(spec(
        "Chai",
        "CHA",
        "Hsti",
        "ref",
        "1b",
        Kernel::RandomRmw(RandomRmw {
            table_elems: 1 << 22,
            updates: 60_000,
            gap: 120,
            ops: 4,
            seed: 31,
        }),
    ));
    v.push(spec(
        "PolyBench",
        "PLY",
        "alu",
        "ref",
        "1b",
        Kernel::PointerChase(PointerChase {
            nodes: 1 << 20,
            hops: 40_000,
            gap: 48,
            ops: 2,
            seed: 32,
        }),
    ));
    v.push(spec(
        "Hashjoin",
        "HSJ",
        "PRH",
        "r12.8M-s12M",
        "1b",
        Kernel::HashBuild(HashBuild {
            table_elems: 1 << 22,
            inserts: 60_000,
            gap: 100,
            seed: 33,
        }),
    ));
    v.push(spec(
        "Chai",
        "CHA",
        "Sel",
        "ref",
        "1b",
        Kernel::RandomRmw(RandomRmw {
            table_elems: 1 << 21,
            updates: 50_000,
            gap: 100,
            ops: 3,
            seed: 34,
        }),
    ));
    v.push(spec(
        "Phoenix",
        "PHE",
        "StrM",
        "keys",
        "1b",
        Kernel::PointerChase(PointerChase {
            nodes: 1 << 19,
            hops: 40_000,
            gap: 60,
            ops: 3,
            seed: 35,
        }),
    ));

    // ---- Class 1c: L1/L2 cache-capacity-bound (5) ----
    let onec = |total_words: usize, passes: usize, gap: u16, ops: u16| {
        Kernel::PartitionedPass(PartitionedPass {
            total_words,
            passes,
            stride_words: 8,
            gap,
            ops,
        })
    };
    // The large gaps keep reference-point MPKI low (the class is defined
    // by decreasing LFMR, not memory intensity; paper Fig 4).
    v.push(spec("Darknet", "DRK", "Res", "ref", "1c", onec(3 << 19, 6, 30, 6)));
    v.push(spec("PARSEC", "PRS", "Flu", "simlarge", "1c", onec(2 << 20, 4, 34, 7)));
    v.push(spec("Parboil", "PAR", "Spmv", "large", "1c", onec(3 << 19, 6, 28, 5)));
    v.push(spec("Rodinia", "ROD", "Bp", "ref", "1c", onec(5 << 18, 7, 36, 8)));
    v.push(spec("Phoenix", "PHE", "Hist", "large", "1c", onec(3 << 19, 5, 32, 6)));

    // ---- Class 2a: L3-contention-bound (5) ----
    let twoa = |block_words: usize, passes: usize, gap: u16, seed: u64| {
        Kernel::SharedHotRmw(SharedHotRmw {
            block_words,
            stride_words: 8,
            total_passes: passes,
            gap,
            seed,
        })
    };
    v.push(spec("PolyBench", "PLY", "GramSch", "ref", "2a", twoa(64 * 1024, 96, 4, 51)));
    v.push(spec("SPLASH-2", "SPL", "FftRev", "simlarge", "2a", twoa(56 * 1024, 104, 4, 52)));
    v.push(spec("SPLASH-2", "SPL", "OcpSlave", "simlarge", "2a", twoa(80 * 1024, 80, 5, 53)));
    v.push(spec("SPLASH-2", "SPL", "Radix", "simlarge", "2a", twoa(48 * 1024, 120, 4, 54)));
    v.push(spec("Rodinia", "ROD", "Srad", "ref", "2a", twoa(72 * 1024, 88, 5, 55)));

    // ---- Class 2b: L1-capacity-bound (6) ----
    let twob = |big_words: usize, med_words: usize, hot: usize, rmw: usize, gap: u16| {
        Kernel::StreamPlusHot(StreamPlusHot {
            big_words,
            med_words,
            hot_words: hot,
            rmw_per_mille: rmw,
            gap,
        })
    };
    v.push(spec("PolyBench", "PLY", "gemver", "2048", "2b", twob(2 << 20, 256 * 1024, 8, 250, 5)));
    v.push(spec("PolyBench", "PLY", "mvt", "2048", "2b", twob(2 << 20, 224 * 1024, 8, 200, 5)));
    v.push(spec("PolyBench", "PLY", "bicg", "2048", "2b", twob(3 << 19, 192 * 1024, 8, 300, 5)));
    v.push(spec("PolyBench", "PLY", "atax", "2048", "2b", twob(3 << 19, 160 * 1024, 8, 220, 5)));
    v.push(spec("SPLASH-2", "SPL", "Lucb", "simlarge", "2b", twob(2 << 20, 256 * 1024, 16, 150, 6)));
    v.push(spec("SPLASH-2", "SPL", "Lunc", "simlarge", "2b", twob(3 << 19, 224 * 1024, 16, 180, 6)));

    // ---- Class 2c: compute-bound (11) ----
    let twoc = |block_words: usize, iters: usize, ops: u16, gap: u16| {
        Kernel::BlockedCompute(BlockedCompute {
            block_words,
            iters,
            ops,
            gap,
        })
    };
    v.push(spec("HPCG", "HPG", "Spm", "104", "2c", twoc(12 * 1024, 256, 8, 4)));
    v.push(spec("Rodinia", "ROD", "Nw", "ref", "2c", twoc(10 * 1024, 288, 6, 4)));
    v.push(spec("PolyBench", "PLY", "3mm", "1024", "2c", twoc(12 * 1024, 256, 10, 3)));
    v.push(spec("PolyBench", "PLY", "2mm", "1024", "2c", twoc(12 * 1024, 240, 10, 3)));
    v.push(spec("PolyBench", "PLY", "Symm", "1024", "2c", twoc(14 * 1024, 224, 9, 3)));
    v.push(spec("PolyBench", "PLY", "Doitgen", "1024", "2c", twoc(11 * 1024, 256, 8, 4)));
    v.push(spec("PolyBench", "PLY", "Gemm", "1024", "2c", twoc(12 * 1024, 256, 11, 3)));
    v.push(spec("PolyBench", "PLY", "Trmm", "1024", "2c", twoc(10 * 1024, 256, 9, 3)));
    v.push(spec("Darknet", "DRK", "Cnn", "ref", "2c", twoc(12 * 1024, 224, 12, 4)));
    v.push(spec("PARSEC", "PRS", "Blk", "simlarge", "2c", twoc(8 * 1024, 320, 10, 4)));
    v.push(spec("Rodinia", "ROD", "Lud", "ref", "2c", twoc(12 * 1024, 240, 9, 4)));

    assert_eq!(v.len(), 44, "Table 8 has 44 representative functions");
    v
}

/// The 100 held-out validation variants (paper §3.5): every
/// representative gets input/size/seed variants until the suite totals
/// 144 functions. Variants keep the family (and hence ground-truth
/// class) but change sizes by ±2x, seeds, or graph input.
pub fn validation_variants() -> Vec<FunctionSpec> {
    let reps = representatives();
    let mut out = Vec::new();
    // Two variants per representative (88) + a third for the first 12.
    for (idx, rep) in reps.iter().enumerate() {
        let n_variants = if idx < 12 { 3 } else { 2 };
        for vi in 0..n_variants {
            let mut s = rep.clone();
            s.representative = false;
            s.paper_class = None;
            s.id.input = format!("{}-v{}", rep.id.input, vi + 1);
            s.kernel = vary(&rep.kernel, vi as u64 + 1);
            out.push(s);
        }
    }
    assert_eq!(out.len(), 100);
    out
}

/// All 144 functions.
pub fn all_functions() -> Vec<FunctionSpec> {
    let mut v = representatives();
    v.extend(validation_variants());
    assert_eq!(v.len(), 144);
    v
}

/// Look up a function by its figure code (e.g. "LIGPrkEmd").
pub fn by_code(code: &str) -> Option<FunctionSpec> {
    all_functions().into_iter().find(|f| f.id.code() == code)
}

/// Produce a same-family variant: scale sizes by 2^(v mod 3 - 1) in
/// {0.5, 1, 2}-ish steps, bump seeds, flip graph input.
fn vary(k: &Kernel, v: u64) -> Kernel {
    let f = match v % 3 {
        0 => 0.5,
        1 => 1.6,
        _ => 0.75,
    };
    let sz = |n: usize| ((n as f64 * f) as usize).max(1024);
    match k {
        Kernel::Stream(s) => {
            let mut s = s.clone();
            s.elems = sz(s.elems);
            Kernel::Stream(s)
        }
        Kernel::GemmStream(g) => {
            let mut g = g.clone();
            // Only grow: shrinking would drop the streamed B matrix into
            // the LLC and change the bottleneck class.
            g.n = sz(g.n).max(g.n);
            g.m = ((g.m as f64 * f) as usize).max(g.m);
            Kernel::GemmStream(g)
        }
        Kernel::HashProbe(h) => {
            let mut h = h.clone();
            h.table_elems = sz(h.table_elems);
            h.seed ^= v.wrapping_mul(0x9E37_79B9);
            Kernel::HashProbe(h)
        }
        Kernel::HashBuild(h) => {
            let mut h = h.clone();
            h.table_elems = sz(h.table_elems);
            h.seed ^= v.wrapping_mul(0x9E37_79B9);
            Kernel::HashBuild(h)
        }
        Kernel::Graph(g) => {
            let mut g = g.clone();
            g.vertices = sz(g.vertices);
            g.seed ^= v;
            if v % 2 == 0 {
                g.input = match g.input {
                    super::graph::GraphInput::RMat => super::graph::GraphInput::Usa,
                    super::graph::GraphInput::Usa => super::graph::GraphInput::RMat,
                };
            }
            Kernel::Graph(g)
        }
        Kernel::Stencil(s) => {
            let mut s = s.clone();
            // Keep rows wide enough that three rows exceed L1 at every
            // core count (the 1a streaming invariant).
            s.width = sz(s.width).max(2048);
            Kernel::Stencil(s)
        }
        Kernel::RandomRmw(r) => {
            let mut r = r.clone();
            r.table_elems = sz(r.table_elems);
            r.seed ^= v;
            Kernel::RandomRmw(r)
        }
        Kernel::PointerChase(p) => {
            let mut p = p.clone();
            p.nodes = sz(p.nodes);
            p.seed ^= v;
            Kernel::PointerChase(p)
        }
        Kernel::PartitionedPass(p) => {
            let mut p = p.clone();
            // The total must stay above the 8 MiB L3 for the class shape.
            p.total_words = sz(p.total_words).max(5 << 18);
            Kernel::PartitionedPass(p)
        }
        Kernel::SharedHotRmw(s) => {
            let mut s = s.clone();
            // Keep the block in the (L2, L3) band that defines the class.
            s.block_words = ((s.block_words as f64 * f) as usize).clamp(48 * 1024, 256 * 1024);
            s.seed ^= v;
            Kernel::SharedHotRmw(s)
        }
        Kernel::StreamPlusHot(s) => {
            let mut s = s.clone();
            // The big stream must stay > L3 and the medium region <= L3
            // for the class invariant.
            s.big_words = ((s.big_words as f64 * f) as usize).max(3 << 19);
            s.med_words = ((s.med_words as f64 * f) as usize).clamp(64 * 1024, 800 * 1024);
            Kernel::StreamPlusHot(s)
        }
        Kernel::BlockedCompute(b) => {
            let mut b = b.clone();
            // Block must stay in (L1, L2].
            b.block_words = ((b.block_words as f64 * f) as usize).clamp(6 * 1024, 30 * 1024);
            Kernel::BlockedCompute(b)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::Scale;
    use std::collections::HashSet;

    #[test]
    fn exactly_44_representatives_and_144_total() {
        assert_eq!(representatives().len(), 44);
        assert_eq!(all_functions().len(), 144);
    }

    #[test]
    fn class_distribution_matches_design() {
        let reps = representatives();
        let count = |c: &str| reps.iter().filter(|r| r.family_class == c).count();
        assert_eq!(count("1a"), 12);
        assert_eq!(count("1b"), 5);
        assert_eq!(count("1c"), 5);
        assert_eq!(count("2a"), 5);
        assert_eq!(count("2b"), 6);
        assert_eq!(count("2c"), 11);
    }

    #[test]
    fn codes_are_unique() {
        let mut seen = HashSet::new();
        for f in all_functions() {
            let key = (f.id.code(), f.id.input.clone());
            assert!(seen.insert(key.clone()), "duplicate {key:?}");
        }
    }

    #[test]
    fn lookup_by_code() {
        assert!(by_code("LIGPrkEmd").is_some());
        assert!(by_code("STRTriad").is_some());
        assert!(by_code("NOPE").is_none());
    }

    #[test]
    fn every_function_generates_nonempty_traces() {
        for f in all_functions() {
            let t = f.trace(2, Scale::tiny());
            assert_eq!(t.len(), 2, "{}", f.id.code());
            let total: usize = t.iter().map(Vec::len).sum();
            assert!(total > 100, "{} produced {} accesses", f.id.code(), total);
        }
    }

    #[test]
    fn variants_share_family_class() {
        for v in validation_variants() {
            assert!(v.paper_class.is_none());
            assert!(!v.representative);
            assert!(["1a", "1b", "1c", "2a", "2b", "2c"].contains(&v.family_class));
        }
    }

    #[test]
    fn representative_codes_match_paper_figures() {
        let reps = representatives();
        let codes: HashSet<String> = reps.iter().map(|r| r.id.code()).collect();
        for expected in [
            "STRAdd", "STRCpy", "STRSca", "STRTriad", "HSJNPO", "LIGCompEms", "LIGPrkEmd",
            "LIGTriEmd", "LIGRadiEms", "LIGKcrEms", "DRKYolo", "CHAHsti", "PLYalu", "HSJPRH",
            "DRKRes", "PRSFlu", "PLYGramSch", "SPLFftRev", "PLYgemver", "SPLLucb", "HPGSpm",
            "RODNw", "PLY3mm", "PLYSymm",
        ] {
            assert!(codes.contains(expected), "missing {expected}");
        }
    }
}
