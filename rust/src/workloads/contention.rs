//! Class-2a family: **L3-contention-bound** (PLYGramSch, SPLFftRev,
//! SPLOcpSlave).
//!
//! Pattern (paper §3.3.4): each thread re-reads and updates a
//! per-thread block that exceeds its private L1/L2 but fits the shared
//! L3 *at low core counts*. High temporal locality (each word is touched
//! several times within a few references — RMW accumulation), low AI,
//! low MPKI. As cores scale, the aggregate footprint (threads ×
//! block) overwhelms the fixed 8 MiB L3; LFMR *rises* with core count
//! and the host collapses under controller queuing — which the NDP
//! system sidesteps with raw internal bandwidth.

use super::{chunks, layout, Scale};
use crate::sim::{Access, Trace};

#[derive(Debug, Clone)]
pub struct SharedHotRmw {
    /// Per-thread block size in words (constant per thread — the
    /// algorithmic tile, e.g. the vector set Gram-Schmidt currently
    /// orthogonalizes). Must exceed the private L2 for the class shape.
    pub block_words: usize,
    /// Words stepped per touch (8 = one touch per cache line keeps the
    /// trace compact while the line footprint stays `block_words * 8` B).
    pub stride_words: usize,
    /// Total block sweeps summed across threads (strong-scaled work:
    /// each thread performs `total_passes / threads` sweeps of its own
    /// block, fractional at high core counts).
    pub total_passes: usize,
    pub gap: u16,
    pub seed: u64,
}

impl SharedHotRmw {
    pub fn trace(&self, threads: usize, scale: Scale) -> Trace {
        let block = scale.n(self.block_words, 4096);
        let stride = self.stride_words.max(1);
        let touches_per_pass = block / stride;
        let total_touches = touches_per_pass * self.total_passes;
        chunks(total_touches, threads)
            .into_iter()
            .enumerate()
            .map(|(tid, (_, my_touches))| {
                let base = layout::private_base(tid);
                let mut t = Vec::with_capacity(my_touches * 5 / 2 + 1);
                for k in 0..my_touches {
                    // Cyclic sweep over this thread's block; each touched
                    // word is loaded twice and (every other touch) stored
                    // — the accumulate pattern that yields high temporal
                    // locality within the 32-reference Step-2 window.
                    let idx = (k % touches_per_pass) * stride;
                    let addr = base + idx as u64 * 8;
                    t.push(Access::load(addr, self.gap, 0).in_bb(1));
                    t.push(Access::load(addr, 0, 0).in_bb(1));
                    if k % 2 == 0 {
                        t.push(Access::store(addr, 1, 1).in_bb(2));
                    }
                }
                t
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate, CoreModel, SystemConfig};

    fn kernel() -> SharedHotRmw {
        SharedHotRmw {
            block_words: 64 * 1024, // 512 KiB per thread: > L2, < L3
            stride_words: 8,
            total_passes: 96,
            gap: 4,
            seed: 11,
        }
    }

    #[test]
    fn lfmr_rises_with_core_count() {
        let k = kernel();
        let lfmr_at = |cores: usize| {
            simulate(
                &SystemConfig::host(cores, CoreModel::OutOfOrder),
                &k.trace(cores, Scale(1.0)),
            )
            .lfmr
        };
        let low = lfmr_at(4);
        let high = lfmr_at(64);
        assert!(
            high > low + 0.3,
            "lfmr should rise with cores: 4c={low} 64c={high}"
        );
    }

    #[test]
    fn host_wins_low_cores_ndp_wins_high_cores() {
        let k = kernel();
        let perf = |cores: usize, ndp: bool| {
            let cfg = if ndp {
                SystemConfig::ndp(cores, CoreModel::OutOfOrder)
            } else {
                SystemConfig::host(cores, CoreModel::OutOfOrder)
            };
            simulate(&cfg, &k.trace(cores, Scale(1.0))).perf()
        };
        assert!(
            perf(4, false) > perf(4, true),
            "host should win at 4 cores"
        );
        assert!(
            perf(64, true) > perf(64, false),
            "NDP should win at 64 cores"
        );
    }

    #[test]
    fn low_mpki_at_reference_count() {
        let k = kernel();
        let r = simulate(
            &SystemConfig::host(4, CoreModel::OutOfOrder),
            &k.trace(4, Scale(1.0)),
        );
        assert!(r.mpki < 11.0, "mpki={}", r.mpki);
    }

    #[test]
    fn word_repeats_within_window() {
        let k = kernel();
        let t = k.trace(1, Scale(0.2));
        // Count immediate same-word repeats in a 32-ref sliding window —
        // the raw signal behind the Step-2 temporal metric.
        let mut repeats = 0usize;
        let tr = &t[0];
        for i in 1..tr.len().min(50_000) {
            let lo = i.saturating_sub(31);
            if tr[lo..i].iter().any(|a| a.addr == tr[i].addr) {
                repeats += 1;
            }
        }
        let frac = repeats as f64 / tr.len().min(50_000) as f64;
        assert!(frac > 0.5, "repeat fraction {frac}");
    }
}
