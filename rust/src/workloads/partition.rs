//! Class-1c family: bottlenecked by **private L1/L2 capacity**.
//!
//! The defining behavior (paper §3.3.3): the total working set is fixed
//! and partitioned across threads, and each thread makes repeated passes
//! over its partition. At low core counts a partition dwarfs the private
//! caches (LFMR high → behaves like class 1b and NDP wins); as cores
//! scale, per-thread partitions shrink into the growing aggregate L1/L2
//! and LFMR *decreases* — the host overtakes NDP (DRKRes, PRSFlu).
//!
//! Reuse distance equals the partition size, far beyond the Step-2
//! window (32 refs), so the architecture-independent *temporal locality
//! metric stays low* even though architectural reuse exists — exactly
//! the paper's point about this class.

use super::{chunks, layout, Scale};
use crate::sim::{Access, Trace};

#[derive(Debug, Clone)]
pub struct PartitionedPass {
    /// Total working set in words (8 B each), split across threads.
    pub total_words: usize,
    /// Sequential passes each thread makes over its partition.
    pub passes: usize,
    /// Stride in words between consecutive touches (1 = fully sequential;
    /// 8 = one word per line — defeats spatial locality in L1).
    pub stride_words: usize,
    pub gap: u16,
    pub ops: u16,
}

impl PartitionedPass {
    pub fn trace(&self, threads: usize, scale: Scale) -> Trace {
        let total = scale.n(self.total_words, 16 * 1024);
        chunks(total, threads)
            .into_iter()
            .map(|(start, len)| {
                // The partition is a contiguous slice of the shared arena —
                // shrinking per-thread as thread count grows.
                let base = layout::SHARED_BASE + start as u64 * 8;
                let mut t = Vec::with_capacity(len * self.passes / self.stride_words + 1);
                for _ in 0..self.passes {
                    let mut i = 0usize;
                    while i < len {
                        t.push(Access::load(base + i as u64 * 8, self.gap, self.ops).in_bb(1));
                        // Light update pass every 4th touch (next word of
                        // the same line: no word-level repeat).
                        if (i / self.stride_words) % 4 == 0 && i + 1 < len {
                            t.push(Access::store(base + (i as u64 + 1) * 8, 1, 1).in_bb(2));
                        }
                        i += self.stride_words;
                    }
                }
                t
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate, CoreModel, SystemConfig};

    fn kernel() -> PartitionedPass {
        PartitionedPass {
            total_words: 3 << 19, // 12 MiB total: exceeds the 8 MiB L3 at
            // 1 core; per-thread slice (192 KiB) fits private L2 by 64 cores
            passes: 6,
            stride_words: 8,
            gap: 10,
            ops: 4,
        }
    }

    #[test]
    fn lfmr_decreases_with_core_count() {
        let k = kernel();
        let lfmr_at = |cores: usize| {
            simulate(
                &SystemConfig::host(cores, CoreModel::OutOfOrder),
                &k.trace(cores, Scale(1.0)),
            )
            .lfmr
        };
        let low = lfmr_at(1);
        let high = lfmr_at(64);
        assert!(
            low > high + 0.3,
            "lfmr should fall with cores: 1c={low} 64c={high}"
        );
    }

    #[test]
    fn ndp_wins_low_cores_host_wins_high_cores() {
        let k = kernel();
        let perf = |cores: usize, ndp: bool| {
            let cfg = if ndp {
                SystemConfig::ndp(cores, CoreModel::OutOfOrder)
            } else {
                SystemConfig::host(cores, CoreModel::OutOfOrder)
            };
            simulate(&cfg, &k.trace(cores, Scale(1.0))).perf()
        };
        assert!(perf(1, true) > perf(1, false), "NDP should win at 1 core");
        assert!(
            perf(64, false) > perf(64, true),
            "host should win at 64 cores"
        );
    }

    #[test]
    fn deterministic_and_partitioned() {
        let k = kernel();
        let t = k.trace(4, Scale(0.1));
        assert_eq!(t, k.trace(4, Scale(0.1)));
        // Partitions are disjoint address ranges.
        for w in t.windows(2) {
            let max0 = w[0].iter().map(|a| a.addr).max().unwrap();
            let min1 = w[1].iter().map(|a| a.addr).min().unwrap();
            assert!(min1 > max0);
        }
    }
}
