//! Hash-join kernels (Balkesen et al. main-memory hash joins, the paper's
//! `Hashjoin` suite).
//!
//! * [`HashProbe`] — `HSJNPO ProbeHashTable`-style: the probe relation
//!   streams sequentially while each key hashes into a DRAM-sized bucket
//!   array. Probes are independent (the next key never depends on the
//!   previous lookup), so an OoO core extracts MLP: class 1a *irregular*.
//! * [`HashBuild`] — `HSJPRH`-style build/histogram phase: random
//!   read-modify-writes at a much lower memory rate (radix computation
//!   between accesses), leaving long dependent-ish gaps: class 1b.

use super::{chunks, layout, Scale};
use crate::sim::{Access, Trace};
use crate::util::rng::mix64;

#[derive(Debug, Clone)]
pub struct HashProbe {
    /// Tuples in the build table (bucket array elements).
    pub table_elems: usize,
    /// Probe keys processed.
    pub probes: usize,
    /// Non-memory instructions per probe (hashing etc.).
    pub gap: u16,
    pub seed: u64,
}

impl HashProbe {
    pub fn trace(&self, threads: usize, scale: Scale) -> Trace {
        let table = scale.n(self.table_elems, 4096);
        let probes = scale.n(self.probes, 4096);
        let keys = layout::SHARED_BASE;
        let buckets = keys + probes as u64 * 8;
        chunks(probes, threads)
            .into_iter()
            .map(|(start, len)| {
                let mut t = Vec::with_capacity(len * 3);
                for i in start..start + len {
                    // Sequential key load.
                    t.push(Access::load(keys + i as u64 * 8, 1, 1).in_bb(1));
                    // Hashed bucket read: 16-byte tuple -> two words.
                    let h = mix64(i as u64 ^ self.seed) % table as u64;
                    let baddr = buckets + h * 16;
                    t.push(Access::load(baddr, self.gap, 1).in_bb(2));
                    t.push(Access::load(baddr + 8, 0, 1).in_bb(2));
                }
                t
            })
            .collect()
    }
}

#[derive(Debug, Clone)]
pub struct HashBuild {
    pub table_elems: usize,
    pub inserts: usize,
    /// Instructions of radix/hash computation between inserts — keeps the
    /// memory rate (MPKI) low while every access still misses.
    pub gap: u16,
    pub seed: u64,
}

impl HashBuild {
    pub fn trace(&self, threads: usize, scale: Scale) -> Trace {
        let table = scale.n(self.table_elems, 4096);
        let inserts = scale.n(self.inserts, 2048);
        let buckets = layout::SHARED_BASE + (1u64 << 30);
        chunks(inserts, threads)
            .into_iter()
            .map(|(start, len)| {
                let mut t = Vec::with_capacity(len * 2);
                for i in start..start + len {
                    let h = mix64(i as u64 ^ self.seed ^ 0xABCD) % table as u64;
                    let baddr = buckets + h * 16;
                    // Read the bucket head, link the tuple into the second
                    // word (same line, distinct words — no word repeat).
                    t.push(Access::load(baddr, self.gap, 2).in_bb(1));
                    t.push(Access::store(baddr + 8, 2, 1).in_bb(1));
                }
                t
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate, CoreModel, SystemConfig};

    #[test]
    fn probe_is_high_mpki_irregular() {
        let k = HashProbe {
            table_elems: 1 << 20, // 16 MiB bucket array
            probes: 100_000,
            gap: 2,
            seed: 7,
        };
        let r = simulate(
            &SystemConfig::host(4, CoreModel::OutOfOrder),
            &k.trace(4, Scale(1.0)),
        );
        assert!(r.mpki > 10.0, "mpki={}", r.mpki);
        assert!(r.lfmr > 0.6, "lfmr={}", r.lfmr);
    }

    #[test]
    fn build_is_low_mpki_high_lfmr() {
        let k = HashBuild {
            table_elems: 1 << 22, // 64 MiB
            inserts: 40_000,
            gap: 100,
            seed: 3,
        };
        let r = simulate(
            &SystemConfig::host(4, CoreModel::OutOfOrder),
            &k.trace(4, Scale(1.0)),
        );
        assert!(r.mpki < 11.0, "mpki={}", r.mpki);
        assert!(r.lfmr > 0.7, "lfmr={}", r.lfmr);
        assert!(r.memory_bound > 0.3, "mb={}", r.memory_bound);
    }

    #[test]
    fn deterministic_and_strong_scaled() {
        let k = HashProbe {
            table_elems: 1 << 16,
            probes: 10_000,
            gap: 2,
            seed: 7,
        };
        let a = k.trace(3, Scale(1.0));
        let b = k.trace(3, Scale(1.0));
        assert_eq!(a, b);
        let n1: usize = k.trace(1, Scale(1.0)).iter().map(Vec::len).sum();
        let n3: usize = a.iter().map(Vec::len).sum();
        assert_eq!(n1, n3);
    }

    #[test]
    fn probe_bucket_reads_cover_table() {
        let k = HashProbe {
            table_elems: 1024,
            probes: 50_000,
            gap: 2,
            seed: 7,
        };
        let t = k.trace(1, Scale(1.0));
        let buckets_base = layout::SHARED_BASE + 50_000 * 8;
        let mut seen = std::collections::HashSet::new();
        for a in &t[0] {
            if a.addr >= buckets_base {
                seen.insert((a.addr - buckets_base) / 16);
            }
        }
        // Nearly all 1024 buckets touched.
        assert!(seen.len() > 1000, "seen={}", seen.len());
    }
}
