//! Class-2c family: **compute-bound** (PLY3mm, PLYSymm, PLYDoitgen,
//! HPGSpm, RODNw, ...).
//!
//! Pattern (paper §3.3.6): cache-blocked kernels with high arithmetic
//! intensity. The per-thread block fits the private L2 (but not L1), so
//! on the host nearly every L1 miss hits L2 (LFMR ≈ 0, MPKI ≈ 0) and the
//! prefetcher covers the sequential block sweeps. On NDP, every L1 miss
//! becomes a DRAM access — the paper reports 44-54% host advantage.
//! High temporal locality comes from the multiply-accumulate re-reads.

use super::{chunks, layout, Scale};
use crate::sim::{Access, Trace};

#[derive(Debug, Clone)]
pub struct BlockedCompute {
    /// Per-thread block in words (choose > L1, <= L2: e.g. 12K words =
    /// 96 KiB).
    pub block_words: usize,
    /// Total block-sweep iterations across all threads (strong-scaled).
    pub iters: usize,
    /// Arithmetic ops per word access — the AI lever (>= ~4 puts the
    /// function in the paper's "high AI" band given the 3-access/word
    /// pattern below).
    pub ops: u16,
    /// Extra non-memory instructions per access.
    pub gap: u16,
}

impl BlockedCompute {
    pub fn trace(&self, threads: usize, scale: Scale) -> Trace {
        let block = scale.n(self.block_words, 2048);
        let iters = scale.n(self.iters, threads.max(2));
        chunks(iters, threads)
            .into_iter()
            .enumerate()
            .map(|(tid, (_, my_iters))| {
                let base = layout::private_base(tid);
                let mut t = Vec::with_capacity(my_iters * block * 3 / 4 + 1);
                for it in 0..my_iters {
                    // Sweep a quarter of the block per iteration (rotating
                    // phase), multiply-accumulate per word: two loads of
                    // the same word (operand reused in the FMA tree) and
                    // a store.
                    let quarter = block / 4;
                    let start = (it % 4) * quarter;
                    for i in start..start + quarter {
                        let addr = base + i as u64 * 8;
                        t.push(Access::load(addr, self.gap, self.ops).in_bb(1));
                        t.push(Access::load(addr, 0, self.ops).in_bb(1));
                        t.push(Access::store(addr, 1, self.ops).in_bb(2));
                    }
                }
                t
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate, CoreModel, SystemConfig};

    fn kernel() -> BlockedCompute {
        BlockedCompute {
            block_words: 12 * 1024, // 96 KiB: > L1, fits L2
            iters: 256,
            ops: 8,
            gap: 4,
        }
    }

    #[test]
    fn host_beats_ndp_at_all_core_counts() {
        let k = kernel();
        for cores in [1usize, 4, 16] {
            let host = simulate(
                &SystemConfig::host(cores, CoreModel::OutOfOrder),
                &k.trace(cores, Scale(1.0)),
            );
            let ndp = simulate(
                &SystemConfig::ndp(cores, CoreModel::OutOfOrder),
                &k.trace(cores, Scale(1.0)),
            );
            assert!(
                host.perf() > ndp.perf(),
                "cores={cores}: host={} ndp={}",
                host.perf(),
                ndp.perf()
            );
        }
    }

    #[test]
    fn low_lfmr_low_mpki_high_ai() {
        let k = kernel();
        let r = simulate(
            &SystemConfig::host(4, CoreModel::OutOfOrder),
            &k.trace(4, Scale(1.0)),
        );
        assert!(r.lfmr < 0.3, "lfmr={}", r.lfmr);
        assert!(r.mpki < 2.0, "mpki={}", r.mpki);
        assert!(r.ai > 8.5, "ai={}", r.ai);
        // 2c functions still pass the Step-1 VTune filter (>30%) but are
        // the least memory-bound class.
        assert!(
            (0.2..0.8).contains(&r.memory_bound),
            "mb={}",
            r.memory_bound
        );
    }

    #[test]
    fn prefetcher_helps() {
        let k = kernel();
        let t = k.trace(4, Scale(1.0));
        let base = simulate(&SystemConfig::host(4, CoreModel::OutOfOrder), &t);
        let pf = simulate(&SystemConfig::host_prefetch(4, CoreModel::OutOfOrder), &t);
        assert!(pf.perf() >= base.perf() * 0.99, "pf should not hurt");
    }

    #[test]
    fn deterministic() {
        let k = kernel();
        assert_eq!(k.trace(3, Scale(0.2)), k.trace(3, Scale(0.2)));
    }
}
