//! Experiment coordinator: schedules the full characterization sweep
//! across worker threads, persists profiles to the results store, and
//! regenerates every paper table/figure through the report harness.
//!
//! ## Fault tolerance
//!
//! The sweep is the hours-long part of the pipeline, so it gets the full
//! crash-safety treatment:
//! * every sweep is keyed by a [`sweep_fingerprint`] (spec codes + sweep
//!   options + store schema), so a cached file is only ever served to
//!   the run that produced it — never a stale or differently-configured
//!   one that merely has the right length;
//! * workers are panic-isolated with bounded retry
//!   ([`crate::util::pool::par_map_catch`]): one bad function becomes a
//!   recorded failure and a degraded (but usable) result set;
//! * each completed profile is appended to a flushed, checksummed
//!   checkpoint; after a crash or Ctrl-C, a `resume` run replays the
//!   intact prefix and recomputes only unfinished functions;
//! * with `--job-timeout` / `--sweep-deadline`, hung or overdue jobs are
//!   soft-cancelled by the pool's watchdog and recorded in the
//!   checkpoint as *retryable* (schema v3), so `--resume` re-runs
//!   exactly them and `damov report health` shows what timed out.

pub mod reports;
pub mod store;

use crate::methodology::step3::{
    profile_all_checkpointed, FunctionProfile, ProfileError, SweepOptions,
};
use crate::sim::{CoreModel, SystemSpec, CORE_SWEEP};
use crate::util::json::Json;
use crate::util::pool::{JobErrorKind, PoolOptions};
use crate::util::telemetry::{self, metrics};
use crate::workloads::{registry, FunctionSpec, Scale};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Duration;

/// Fingerprint identifying a sweep: which functions, which options,
/// which systems (each [`SystemSpec`]'s own content fingerprint is
/// folded in, so editing a custom spec's JSON — or respelling it into
/// an identical normal form — changes or preserves the sweep key
/// exactly when it should), which record layout. Caches and checkpoints
/// are only trusted when their recorded fingerprint matches the sweep
/// being requested. Keyed by [`store::RECORD_VERSION`] (not the
/// document schema version), so a document-schema bump that leaves
/// records unchanged — like v2→v3 — keeps old checkpoints resumable
/// and old caches servable.
pub fn sweep_fingerprint(specs: &[FunctionSpec], opt: &SweepOptions) -> String {
    let mut text = format!(
        "schema={};scale={:x};",
        store::RECORD_VERSION,
        opt.scale.0.to_bits(),
    );
    for sys in &opt.systems {
        text.push_str(&sys.name);
        text.push(':');
        text.push_str(&sys.fingerprint());
        text.push(',');
    }
    text.push(';');
    for m in opt.core_models {
        text.push_str(match m {
            CoreModel::OutOfOrder => "ooo,",
            CoreModel::InOrder => "inorder,",
        });
    }
    text.push(';');
    for &c in CORE_SWEEP.iter() {
        text.push_str(&format!("{c},"));
    }
    text.push(';');
    for s in specs {
        text.push_str(&s.id.code());
        text.push(':');
        text.push_str(&s.id.input);
        text.push(',');
    }
    format!("{:016x}", crate::util::fault::key_of(&text))
}

/// Top-level driver owning the profile cache.
pub struct Coordinator {
    pub results_dir: PathBuf,
    pub threads: usize,
    /// Retries per panicking worker job before it is recorded as failed.
    pub max_retries: u32,
    /// Resume from an existing checkpoint instead of starting over.
    pub resume: bool,
    /// Per-job wall-clock budget (`--job-timeout`): overdue jobs are
    /// soft-cancelled and recorded as retryable. `None` = unbounded.
    pub job_timeout: Option<Duration>,
    /// Sweep-wide wall-clock budget (`--sweep-deadline`). `None` =
    /// unbounded.
    pub sweep_deadline: Option<Duration>,
}

impl Coordinator {
    pub fn new(results_dir: impl Into<PathBuf>, threads: usize) -> Coordinator {
        let results_dir = results_dir.into();
        std::fs::create_dir_all(&results_dir).ok();
        Coordinator {
            results_dir,
            threads,
            max_retries: 2,
            resume: false,
            job_timeout: None,
            sweep_deadline: None,
        }
    }

    /// Configure recovery behavior (`--max-retries`, `--resume`).
    pub fn with_recovery(mut self, max_retries: u32, resume: bool) -> Coordinator {
        self.max_retries = max_retries;
        self.resume = resume;
        self
    }

    /// Configure wall-clock budgets (`--job-timeout`, `--sweep-deadline`).
    pub fn with_deadlines(
        mut self,
        job_timeout: Option<Duration>,
        sweep_deadline: Option<Duration>,
    ) -> Coordinator {
        self.job_timeout = job_timeout;
        self.sweep_deadline = sweep_deadline;
        self
    }

    fn pool_options(&self) -> PoolOptions {
        PoolOptions {
            threads: self.threads,
            max_retries: self.max_retries,
            job_timeout: self.job_timeout,
            sweep_deadline: self.sweep_deadline,
        }
    }

    fn cache_path(&self, tag: &str) -> PathBuf {
        self.results_dir.join(format!("profiles-{tag}.json"))
    }

    fn checkpoint_path(&self, tag: &str) -> PathBuf {
        self.results_dir.join(format!("checkpoint-{tag}.jsonl"))
    }

    /// Profile the given functions, using the on-disk cache when its
    /// fingerprint matches this exact sweep (pass `refresh=true` to
    /// force recompute). Survives worker panics (bounded retry, then a
    /// recorded failure) and interruption (incremental checkpoint;
    /// `resume` restarts from the last completed function). On partial
    /// failure the completed profiles are returned and the checkpoint is
    /// kept so a follow-up `--resume` run can finish the rest.
    pub fn profiles(
        &self,
        tag: &str,
        specs: &[FunctionSpec],
        opt: SweepOptions,
        refresh: bool,
    ) -> Vec<FunctionProfile> {
        let fingerprint = sweep_fingerprint(specs, &opt);
        let _sweep_span = telemetry::span_args(
            "sweep",
            vec![
                ("tag".to_string(), Json::from(tag)),
                ("functions".to_string(), Json::from(specs.len())),
            ],
        );
        let path = self.cache_path(tag);
        if !refresh {
            if let Some(cached) = store::load_profiles_keyed(&path, &fingerprint) {
                if cached.len() == specs.len() {
                    return cached;
                }
            }
        }

        // Recover completed functions from a previous interrupted run.
        let ckpt_path = self.checkpoint_path(tag);
        let mut done: BTreeMap<String, FunctionProfile> = BTreeMap::new();
        if self.resume && !refresh {
            for p in store::load_checkpoint(&ckpt_path, &fingerprint) {
                done.insert(p.code.clone(), p);
            }
            if !done.is_empty() {
                // Seed the registry with the interrupted run's counters so
                // `damov report telemetry` shows cumulative counts.
                if let Some(snap) = store::load_checkpoint_metrics(&ckpt_path, &fingerprint) {
                    metrics::absorb(&snap);
                }
                metrics::counter("sweep.functions_recovered").add(done.len() as u64);
                telemetry::info(
                    "resume",
                    &[
                        ("recovered", Json::from(done.len())),
                        ("total", Json::from(specs.len())),
                        ("checkpoint", Json::from(ckpt_path.display().to_string())),
                    ],
                );
            }
        }
        let todo: Vec<FunctionSpec> = specs
            .iter()
            .filter(|s| !done.contains_key(&s.id.code()))
            .cloned()
            .collect();

        let mut failures: Vec<ProfileError> = Vec::new();
        if !todo.is_empty() {
            // Checkpoint as we go; losing the checkpoint is a warning,
            // not a failure — the sweep itself continues.
            let writer = match store::CheckpointWriter::create(&ckpt_path, &fingerprint, !done.is_empty())
            {
                Ok(w) => Some(w),
                Err(e) => {
                    telemetry::warn(
                        "degraded",
                        &[
                            ("component", Json::from("checkpoint")),
                            ("detail", Json::from(format!(
                                "{e} (sweep continues without crash recovery)"
                            ))),
                        ],
                    );
                    None
                }
            };
            let results = profile_all_checkpointed(&todo, opt, &self.pool_options(), |p| {
                if let Some(w) = &writer {
                    if let Err(e) = w.append(p) {
                        telemetry::warn(
                            "degraded",
                            &[
                                ("component", Json::from("checkpoint")),
                                ("detail", Json::from(e.to_string())),
                            ],
                        );
                    } else {
                        // Cumulative counters ride along with every record so
                        // a crash leaves them for --resume to absorb.
                        let _ = w.append_metrics(&metrics::snapshot());
                    }
                }
            });
            for r in results {
                match r {
                    Ok(p) => {
                        done.insert(p.code.clone(), p);
                    }
                    Err(e) => failures.push(e),
                }
            }
            // Mark every failure retryable in the checkpoint (schema v3):
            // a follow-up --resume run recomputes exactly these, and the
            // health report can say *why* they are missing.
            if let Some(w) = &writer {
                for e in &failures {
                    let rec = store::RetryableRecord {
                        code: e.code.clone(),
                        kind: e.kind.label().to_string(),
                        attempts: e.attempts,
                        message: e.message.clone(),
                    };
                    if let Err(err) = w.append_retryable(&rec) {
                        telemetry::warn(
                            "degraded",
                            &[
                                ("component", Json::from("checkpoint")),
                                ("detail", Json::from(format!(
                                    "could not record retryable failure for {}: {err}",
                                    e.code
                                ))),
                            ],
                        );
                    }
                }
            }
        }

        // Assemble in spec order from recovered + freshly computed.
        let profiles: Vec<FunctionProfile> = specs
            .iter()
            .filter_map(|s| done.remove(&s.id.code()))
            .collect();

        if failures.is_empty() && profiles.len() == specs.len() {
            if let Err(e) = store::save_profiles_keyed(&path, &profiles, &fingerprint) {
                telemetry::warn(
                    "store",
                    &[("detail", Json::from(format!(
                        "could not persist profiles to {path:?}: {e}"
                    )))],
                );
            } else {
                // The cache now holds everything; the checkpoint is spent.
                std::fs::remove_file(&ckpt_path).ok();
            }
        } else {
            metrics::counter("sweep.functions_failed").add(failures.len() as u64);
            let timed_out = failures.iter().filter(|e| e.kind == JobErrorKind::TimedOut).count();
            let cancelled = failures.iter().filter(|e| e.kind == JobErrorKind::Cancelled).count();
            metrics::counter("sweep.functions_timed_out").add(timed_out as u64);
            metrics::counter("sweep.functions_cancelled").add(cancelled as u64);
            for e in &failures {
                telemetry::error(
                    "job-failed",
                    &[
                        ("code", Json::from(e.code.as_str())),
                        ("kind", Json::from(e.kind.label())),
                        ("attempts", Json::from(e.attempts as u64)),
                        ("error", Json::from(e.message.as_str())),
                    ],
                );
            }
            telemetry::warn(
                "degraded",
                &[
                    ("component", Json::from("sweep")),
                    ("tag", Json::from(tag)),
                    ("failed", Json::from(specs.len() - profiles.len())),
                    ("total", Json::from(specs.len())),
                    ("detail", Json::from("checkpoint kept for --resume")),
                ],
            );
        }
        profiles
    }

    /// The representative sweep's specs (optionally truncated to the
    /// first `limit`) and options, shared by [`representative_profiles`]
    /// and the health report so their fingerprints always agree.
    ///
    /// [`representative_profiles`]: Coordinator::representative_profiles
    pub fn representative_sweep(
        scale: Scale,
        limit: Option<usize>,
    ) -> (Vec<FunctionSpec>, SweepOptions) {
        Coordinator::representative_sweep_systems(scale, limit, SystemSpec::paper_sweep())
    }

    /// [`representative_sweep`](Coordinator::representative_sweep) over
    /// an explicit system list (`--systems`): same specs and core
    /// models, custom [`SystemSpec`]s.
    pub fn representative_sweep_systems(
        scale: Scale,
        limit: Option<usize>,
        systems: Vec<SystemSpec>,
    ) -> (Vec<FunctionSpec>, SweepOptions) {
        let mut specs = registry::representatives();
        if let Some(l) = limit {
            specs.truncate(l);
        }
        let opt = SweepOptions {
            core_models: &[CoreModel::OutOfOrder, CoreModel::InOrder],
            systems,
            scale,
        };
        (specs, opt)
    }

    /// The 44 representatives at full scale with both core models and
    /// the NUCA variant — everything the report suite needs.
    pub fn representative_profiles(&self, refresh: bool) -> Vec<FunctionProfile> {
        self.representative_profiles_scaled(refresh, Scale::full(), None)
    }

    /// [`representative_profiles`] at an arbitrary scale / subset — CI
    /// smoke runs use a tiny scale and a `--limit` prefix so a whole
    /// sweep (plus a deadline-recovery resume) fits in seconds.
    ///
    /// [`representative_profiles`]: Coordinator::representative_profiles
    pub fn representative_profiles_scaled(
        &self,
        refresh: bool,
        scale: Scale,
        limit: Option<usize>,
    ) -> Vec<FunctionProfile> {
        let (specs, opt) = Coordinator::representative_sweep(scale, limit);
        self.profiles("reps", &specs, opt, refresh)
    }

    /// [`representative_profiles_scaled`] over an explicit system list
    /// (`--systems`). The cache/checkpoint tag stays `reps`; the sweep
    /// fingerprint (which embeds every spec's content hash) keeps runs
    /// over different system lists from ever serving each other's
    /// cached profiles.
    ///
    /// [`representative_profiles_scaled`]: Coordinator::representative_profiles_scaled
    pub fn representative_profiles_systems(
        &self,
        refresh: bool,
        scale: Scale,
        limit: Option<usize>,
        systems: Vec<SystemSpec>,
    ) -> Vec<FunctionProfile> {
        let (specs, opt) = Coordinator::representative_sweep_systems(scale, limit, systems);
        self.profiles("reps", &specs, opt, refresh)
    }

    /// Outstanding retryable failures of a sweep's checkpoint: functions
    /// recorded as timed-out / cancelled / panicked that have not since
    /// completed. Empty when there is no checkpoint (e.g. after a fully
    /// successful sweep retires it).
    pub fn retryable(
        &self,
        tag: &str,
        specs: &[FunctionSpec],
        opt: &SweepOptions,
    ) -> Vec<store::RetryableRecord> {
        let fingerprint = sweep_fingerprint(specs, opt);
        let ckpt = self.checkpoint_path(tag);
        let completed: std::collections::BTreeSet<String> =
            store::load_checkpoint(&ckpt, &fingerprint)
                .into_iter()
                .map(|p| p.code)
                .collect();
        store::load_checkpoint_retryable(&ckpt, &fingerprint)
            .into_iter()
            .filter(|r| !completed.contains(&r.code))
            .collect()
    }

    /// [`retryable`](Coordinator::retryable) for the representative
    /// sweep (matching `scale`/`limit` of the profiles call).
    pub fn representative_retryable(
        &self,
        scale: Scale,
        limit: Option<usize>,
    ) -> Vec<store::RetryableRecord> {
        self.representative_retryable_systems(scale, limit, SystemSpec::paper_sweep())
    }

    /// [`representative_retryable`](Coordinator::representative_retryable)
    /// for a sweep over an explicit system list (`--systems`).
    pub fn representative_retryable_systems(
        &self,
        scale: Scale,
        limit: Option<usize>,
        systems: Vec<SystemSpec>,
    ) -> Vec<store::RetryableRecord> {
        let (specs, opt) = Coordinator::representative_sweep_systems(scale, limit, systems);
        self.retryable("reps", &specs, &opt)
    }

    /// The 100 held-out validation variants (out-of-order host/NDP only —
    /// what the validation needs).
    pub fn holdout_profiles(&self, refresh: bool) -> Vec<FunctionProfile> {
        let specs = registry::validation_variants();
        let opt = SweepOptions {
            core_models: &[CoreModel::OutOfOrder],
            systems: SystemSpec::default_sweep(),
            scale: Scale::full(),
        };
        self.profiles("holdout", &specs, opt, refresh)
    }
}

/// Resolve the default results directory (`results/` beside Cargo.toml).
pub fn default_results_dir() -> PathBuf {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("results");
    if p.parent().map(|d| d.exists()).unwrap_or(false) {
        p
    } else {
        PathBuf::from("results")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coordinator_caches_profiles() {
        let dir = std::env::temp_dir().join(format!("damov-test-{}", std::process::id()));
        let coord = Coordinator::new(&dir, 4);
        let specs: Vec<_> = registry::representatives().into_iter().take(2).collect();
        let opt = SweepOptions {
            scale: Scale(0.05),
            ..Default::default()
        };
        let a = coord.profiles("t", &specs, opt.clone(), true);
        assert_eq!(a.len(), 2);
        // Second call must hit the cache (same values back).
        let b = coord.profiles("t", &specs, opt, false);
        assert_eq!(b.len(), 2);
        assert_eq!(a[0].code, b[0].code);
        assert!((a[0].mpki - b[0].mpki).abs() < 1e-9);
        assert_eq!(a[0].runs.len(), b[0].runs.len());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stale_cache_with_matching_length_is_rejected() {
        let dir = std::env::temp_dir().join(format!("damov-stale-{}", std::process::id()));
        let coord = Coordinator::new(&dir, 4);
        let opt = SweepOptions {
            scale: Scale(0.05),
            ..Default::default()
        };
        let reps = registry::representatives();
        let first: Vec<_> = reps.iter().take(2).cloned().collect();
        let second: Vec<_> = reps.iter().skip(2).take(2).cloned().collect();
        let a = coord.profiles("s", &first, opt.clone(), true);
        // Same tag, same *length*, different specs: the pre-fingerprint
        // cache served `a` here. Now the fingerprint mismatch forces a
        // recompute of the right functions.
        let b = coord.profiles("s", &second, opt.clone(), false);
        assert_eq!(b.len(), 2);
        assert_ne!(a[0].code, b[0].code);
        assert_eq!(b[0].code, second[0].id.code());
        // Different options (scale) must also miss the cache.
        let opt2 = SweepOptions {
            scale: Scale(0.06),
            ..Default::default()
        };
        assert_ne!(
            sweep_fingerprint(&second, &opt),
            sweep_fingerprint(&second, &opt2)
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resume_recovers_checkpointed_functions() {
        let dir = std::env::temp_dir().join(format!("damov-resume-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let specs: Vec<_> = registry::representatives().into_iter().take(3).collect();
        let opt = SweepOptions {
            scale: Scale(0.05),
            ..Default::default()
        };
        let fp = sweep_fingerprint(&specs, &opt);

        // Baseline, computed without any persistence in the way.
        let clean = Coordinator::new(&dir, 2).profiles("base", &specs, opt.clone(), true);
        assert_eq!(clean.len(), 3);

        // Emulate a sweep killed after two functions: a checkpoint with
        // records 0 and 1 (and no cache file for this tag).
        let ckpt = dir.join("checkpoint-r.jsonl");
        let w = store::CheckpointWriter::create(&ckpt, &fp, false).unwrap();
        w.append(&clean[0]).unwrap();
        w.append(&clean[1]).unwrap();
        drop(w);

        let resumed = Coordinator::new(&dir, 2)
            .with_recovery(0, true)
            .profiles("r", &specs, opt, false);
        assert_eq!(resumed.len(), 3);
        // (The "only unfinished functions are recomputed" property is
        // asserted via profile_call_count in tests/fault_injection.rs,
        // where no other test runs in the same process.)
        for (r, c) in resumed.iter().zip(clean.iter()) {
            assert_eq!(r.code, c.code);
            assert!((r.mpki - c.mpki).abs() < 1e-12);
        }
        // Completed sweep: cache written, checkpoint retired.
        assert!(!ckpt.exists());
        assert!(store::load_profiles_keyed(&dir.join("profiles-r.json"), &fp).is_some());
        std::fs::remove_dir_all(&dir).ok();
    }
}
