//! Experiment coordinator: schedules the full characterization sweep
//! across worker threads, persists profiles to the results store, and
//! regenerates every paper table/figure through the report harness.

pub mod reports;
pub mod store;

use crate::methodology::step3::{profile_all, FunctionProfile, SweepOptions};
use crate::sim::CoreModel;
use crate::workloads::{registry, FunctionSpec, Scale};
use std::path::{Path, PathBuf};

/// Top-level driver owning the profile cache.
pub struct Coordinator {
    pub results_dir: PathBuf,
    pub threads: usize,
}

impl Coordinator {
    pub fn new(results_dir: impl Into<PathBuf>, threads: usize) -> Coordinator {
        let results_dir = results_dir.into();
        std::fs::create_dir_all(&results_dir).ok();
        Coordinator {
            results_dir,
            threads,
        }
    }

    fn cache_path(&self, tag: &str) -> PathBuf {
        self.results_dir.join(format!("profiles-{tag}.json"))
    }

    /// Profile the given functions, using the on-disk cache when the tag
    /// matches a previous run (pass `refresh=true` to force recompute).
    pub fn profiles(
        &self,
        tag: &str,
        specs: &[FunctionSpec],
        opt: SweepOptions,
        refresh: bool,
    ) -> Vec<FunctionProfile> {
        let path = self.cache_path(tag);
        if !refresh {
            if let Some(cached) = store::load_profiles(&path) {
                if cached.len() == specs.len() {
                    return cached;
                }
            }
        }
        let profiles = profile_all(specs, opt, self.threads);
        if let Err(e) = store::save_profiles(&path, &profiles) {
            eprintln!("warning: could not persist profiles to {path:?}: {e}");
        }
        profiles
    }

    /// The 44 representatives at full scale with both core models and
    /// the NUCA variant — everything the report suite needs.
    pub fn representative_profiles(&self, refresh: bool) -> Vec<FunctionProfile> {
        let specs = registry::representatives();
        let opt = SweepOptions {
            core_models: &[CoreModel::OutOfOrder, CoreModel::InOrder],
            nuca: true,
            scale: Scale::full(),
        };
        self.profiles("reps", &specs, opt, refresh)
    }

    /// The 100 held-out validation variants (out-of-order host/NDP only —
    /// what the validation needs).
    pub fn holdout_profiles(&self, refresh: bool) -> Vec<FunctionProfile> {
        let specs = registry::validation_variants();
        let opt = SweepOptions {
            core_models: &[CoreModel::OutOfOrder],
            nuca: false,
            scale: Scale::full(),
        };
        self.profiles("holdout", &specs, opt, refresh)
    }
}

/// Resolve the default results directory (`results/` beside Cargo.toml).
pub fn default_results_dir() -> PathBuf {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("results");
    if p.parent().map(|d| d.exists()).unwrap_or(false) {
        p
    } else {
        PathBuf::from("results")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coordinator_caches_profiles() {
        let dir = std::env::temp_dir().join(format!("damov-test-{}", std::process::id()));
        let coord = Coordinator::new(&dir, 4);
        let specs: Vec<_> = registry::representatives().into_iter().take(2).collect();
        let opt = SweepOptions {
            scale: Scale(0.05),
            ..Default::default()
        };
        let a = coord.profiles("t", &specs, opt, true);
        assert_eq!(a.len(), 2);
        // Second call must hit the cache (same values back).
        let b = coord.profiles("t", &specs, opt, false);
        assert_eq!(b.len(), 2);
        assert_eq!(a[0].code, b[0].code);
        assert!((a[0].mpki - b[0].mpki).abs() < 1e-9);
        assert_eq!(a[0].runs.len(), b[0].runs.len());
        std::fs::remove_dir_all(&dir).ok();
    }
}
