//! Report harness: regenerates every table and figure of the paper's
//! evaluation as aligned text tables (+ the numbers behind them), from
//! the profiles produced by the coordinator. See DESIGN.md §5 for the
//! experiment index; EXPERIMENTS.md records paper-vs-measured.

use crate::methodology::classify::{self, Class, Features};
use crate::methodology::cluster;
use crate::methodology::step3::FunctionProfile;
use crate::sim::accel::{self, AccelConfig};
use crate::sim::engine::{simulate_opt, SimOptions};
use crate::sim::{simulate, CoreModel, SystemConfig, CORE_SWEEP};
use crate::util::stats::{geomean, Summary};
use crate::util::table::{bar, f, Table};
use crate::workloads::{registry, Scale};

/// The paper's 12 deep-dive functions (Fig 5): two per class.
pub const FIG5_FUNCTIONS: [(&str, &str); 12] = [
    ("HSJNPO", "1a"),
    ("LIGPrkEmd", "1a"),
    ("CHAHsti", "1b"),
    ("PLYalu", "1b"),
    ("DRKRes", "1c"),
    ("PRSFlu", "1c"),
    ("PLYGramSch", "2a"),
    ("SPLFftRev", "2a"),
    ("PLYgemver", "2b"),
    ("SPLLucb", "2b"),
    ("HPGSpm", "2c"),
    ("RODNw", "2c"),
];

fn by_code<'a>(profiles: &'a [FunctionProfile], code: &str) -> Option<&'a FunctionProfile> {
    profiles.iter().find(|p| p.code == code)
}

/// Distinct system labels of a profile, in first-appearance (sweep)
/// order — the row grouping of the per-system report tables. Custom
/// `--systems` sweeps show up here under their own spec names.
fn system_labels(p: &FunctionProfile) -> Vec<&str> {
    let mut out: Vec<&str> = Vec::new();
    for r in &p.runs {
        if !out.contains(&r.system.as_str()) {
            out.push(r.system.as_str());
        }
    }
    out
}

const OOO: CoreModel = CoreModel::OutOfOrder;

// ---------------------------------------------------------------- tab1

/// Table 1: evaluated system configurations.
pub fn tab1() -> String {
    let host = SystemConfig::host(4, OOO);
    let ndp = SystemConfig::ndp(4, OOO);
    let mut t = Table::new(
        "Table 1: Evaluated Host CPU and NDP system configurations",
        &["component", "parameter", "value"],
    );
    let l2 = host.l2.unwrap();
    let l3 = host.l3.unwrap();
    let rows: Vec<(&str, &str, String)> = vec![
        ("Processor", "cores", "1, 4, 16, 64, 256 @2.4 GHz".into()),
        ("Processor", "models", "4-wide out-of-order / in-order".into()),
        ("Processor", "buffers", format!("{}-entry ROB; {}-entry LSQ", host.rob, host.lsq)),
        ("Processor", "MSHRs", format!("{}", host.mshrs)),
        ("L1 cache", "geometry", format!("{} KiB, {}-way, {}-cycle, 64 B lines, LRU", host.l1.size_bytes >> 10, host.l1.ways, host.l1.latency_cycles)),
        ("L1 cache", "energy", format!("{}/{} pJ hit/miss", host.l1.epj_hit, host.l1.epj_miss)),
        ("L2 cache", "geometry", format!("{} KiB, {}-way, {}-cycle (host only)", l2.size_bytes >> 10, l2.ways, l2.latency_cycles)),
        ("L2 cache", "energy", format!("{}/{} pJ hit/miss", l2.epj_hit, l2.epj_miss)),
        ("L3 cache", "geometry", format!("{} MiB, {} banks, {}-way, {}-cycle, inclusive (host only)", l3.size_bytes >> 20, host.l3_banks, l3.ways, l3.latency_cycles)),
        ("L3 cache", "energy", format!("{}/{} pJ hit/miss", l3.epj_hit, l3.epj_miss)),
        ("Prefetcher", "config", format!("stream: {}-degree, {} streams (host+pf only)", host.pf_degree, host.pf_streams)),
        ("NDP", "hierarchy", "read-only private L1 only; no prefetcher".into()),
        ("Main memory", "geometry", format!("HMC-like: {} vaults x {} banks, {} B rows, open page", host.dram.vaults, host.dram.banks_per_vault, host.dram.row_bytes)),
        ("Main memory", "host peak BW", format!("{:.0} GB/s (off-chip link)", host.dram.host_peak_bw / 1e9)),
        ("Main memory", "NDP peak BW", format!("{:.0} GB/s (internal)", ndp.dram.ndp_peak_bw / 1e9)),
        ("Main memory", "energy", format!("{}/{}/{} pJ/bit internal/logic/link", host.dram.epj_bit_internal, host.dram.epj_bit_logic, host.dram.epj_bit_link)),
        ("NoC (NUCA)", "config", format!("2-D mesh, {} cyc/hop, M/D/1 contention; L3 2 MiB/core", host.noc.cycles_per_hop)),
    ];
    for (a, b, c) in rows {
        t.row(vec![a.into(), b.into(), c]);
    }
    t.render()
}

// ---------------------------------------------------------------- fig1

/// Fig 1: roofline coordinates + LLC MPKI vs NDP speedup for the 44
/// representative functions, with the paper's four suitability
/// categories.
pub fn fig1(reps: &[FunctionProfile]) -> String {
    let mut t = Table::new(
        "Fig 1: roofline (AI, perf) and MPKI vs NDP speedup, 44 functions",
        &["function", "class", "AI", "MPKI", "ndp@min", "ndp@max", "category"],
    );
    for p in reps {
        let speedups: Vec<f64> = CORE_SWEEP
            .iter()
            .map(|&c| p.ndp_speedup(OOO, c))
            .filter(|s| s.is_finite())
            .collect();
        let min = speedups.iter().copied().fold(f64::MAX, f64::min);
        let max = speedups.iter().copied().fold(f64::MIN, f64::max);
        let category = if min > 1.05 {
            "Faster on NDP"
        } else if max < 0.95 {
            "Faster on CPU"
        } else if max > 1.10 && min < 0.95 {
            "Depends"
        } else {
            "Similar on CPU/NDP"
        };
        t.row(vec![
            p.code.clone(),
            p.paper_class.unwrap_or("?").into(),
            f(p.ai),
            f(p.mpki),
            f(min),
            f(max),
            category.into(),
        ]);
    }
    let mut out = t.render();
    out.push_str(
        "\nPaper shape: all high-MPKI functions are Faster-on-NDP; some low-MPKI\n\
         functions still benefit (latency-bound), 2c functions are Faster-on-CPU,\n\
         and 1c/2a functions are core-count dependent.\n",
    );
    out
}

// ---------------------------------------------------------------- fig3

/// Fig 3: locality-based K-means clustering (k=2 over spatial/temporal).
/// `pjrt_assign` may supply assignments computed through the PJRT
/// k-means artifact to display instead of the Rust fallback.
pub fn fig3(reps: &[FunctionProfile], pjrt_assign: Option<&[usize]>) -> String {
    let points = fig3_points(reps);
    let (assign_rust, _) = cluster::kmeans(&points, 2, 50, 42);
    let assign = pjrt_assign.unwrap_or(&assign_rust);
    let mut t = Table::new(
        "Fig 3: locality-based clustering of 44 representative functions",
        &["function", "class", "spatial", "temporal", "cluster"],
    );
    // Identify which cluster is the high-temporal one for stable labels.
    let mean_t: Vec<f64> = (0..2)
        .map(|c| {
            let sel: Vec<f64> = reps
                .iter()
                .zip(assign)
                .filter(|(_, &a)| a == c)
                .map(|(p, _)| p.locality.temporal)
                .collect();
            crate::util::stats::mean(&sel)
        })
        .collect();
    let high_cluster = if mean_t[0] > mean_t[1] { 0 } else { 1 };
    for (p, &a) in reps.iter().zip(assign) {
        let label = if a == high_cluster { "high-temporal" } else { "low-temporal" };
        t.row(vec![
            p.code.clone(),
            p.paper_class.unwrap_or("?").into(),
            f(p.locality.spatial),
            f(p.locality.temporal),
            label.into(),
        ]);
    }
    let mut out = t.render();
    // Agreement between clustering and the class-1x/2x split.
    let agree = reps
        .iter()
        .zip(assign)
        .filter(|(p, &a)| {
            let is_high = a == high_cluster;
            let is_class2 = p.paper_class.map(|c| c.starts_with('2')).unwrap_or(false);
            is_high == is_class2
        })
        .count();
    out.push_str(&format!(
        "\nCluster vs class-group agreement: {}/{} functions\n",
        agree,
        reps.len()
    ));
    out
}

/// Feature points for Fig 3 (spatial, temporal).
pub fn fig3_points(reps: &[FunctionProfile]) -> Vec<Vec<f64>> {
    reps.iter()
        .map(|p| vec![p.locality.spatial, p.locality.temporal])
        .collect()
}

// ---------------------------------------------------------------- fig4

/// Fig 4: L3 MPKI and LFMR per function, grouped by class.
pub fn fig4(reps: &[FunctionProfile]) -> String {
    let mut t = Table::new(
        "Fig 4: LLC MPKI and LFMR (host, 4 cores) per class",
        &["class", "function", "MPKI", "LFMR", "LFMR@1c", "LFMR@256c"],
    );
    let mut sorted: Vec<&FunctionProfile> = reps.iter().collect();
    sorted.sort_by_key(|p| (p.paper_class.unwrap_or("?"), p.code.clone()));
    for p in sorted {
        t.row(vec![
            p.paper_class.unwrap_or("?").into(),
            p.code.clone(),
            f(p.mpki),
            f(p.lfmr),
            f(*p.lfmr_by_cores.first().unwrap_or(&f64::NAN)),
            f(*p.lfmr_by_cores.last().unwrap_or(&f64::NAN)),
        ]);
    }
    t.render()
}

// ---------------------------------------------------------------- fig5

/// Fig 5: performance scaling of the 12 deep-dive functions on the three
/// systems, normalized to one host core.
pub fn fig5(reps: &[FunctionProfile]) -> String {
    let mut out = String::new();
    for (code, class) in FIG5_FUNCTIONS {
        let Some(p) = by_code(reps, code) else { continue };
        let mut t = Table::new(
            &format!("Fig 5 — {code} (class {class}): normalized performance"),
            &["cores", "host", "host+pf", "ndp", "ndp/host"],
        );
        for &c in CORE_SWEEP.iter() {
            t.row(vec![
                c.to_string(),
                f(p.norm_perf("host", OOO, c)),
                f(p.norm_perf("host+pf", OOO, c)),
                f(p.norm_perf("ndp", OOO, c)),
                f(p.ndp_speedup(OOO, c)),
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out
}

// ---------------------------------------------------------------- fig6

/// Fig 6: host IPC vs utilized DRAM bandwidth for class-1a functions.
pub fn fig6(reps: &[FunctionProfile]) -> String {
    let mut out = String::new();
    for code in ["HSJNPO", "LIGPrkEmd"] {
        let Some(p) = by_code(reps, code) else { continue };
        let mut t = Table::new(
            &format!("Fig 6 — {code}: host IPC vs utilized DRAM bandwidth"),
            &["cores", "IPC", "BW (GB/s)", "utilization"],
        );
        for &c in CORE_SWEEP.iter() {
            if let Some(r) = p.run("host", OOO, c) {
                t.row(vec![
                    c.to_string(),
                    f(r.result.ipc),
                    f(r.result.bw_bytes_s / 1e9),
                    bar(r.result.dram_rho, 20),
                ]);
            }
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out.push_str("Paper shape: IPC saturates exactly where BW reaches the off-chip peak.\n");
    out
}

// ------------------------------------------------------- energy figures

/// Shared renderer for the energy-breakdown figures (7, 9, 10, 12, 14, 15).
pub fn fig_energy(reps: &[FunctionProfile], fig: &str, codes: [&str; 2], class: &str) -> String {
    let mut out = String::new();
    for code in codes {
        let Some(p) = by_code(reps, code) else { continue };
        let mut t = Table::new(
            &format!("Fig {fig} — {code} (class {class}): energy breakdown (J)"),
            &["cores", "system", "L1", "L2", "L3", "DRAM", "link", "total"],
        );
        for &c in CORE_SWEEP.iter() {
            for sys in system_labels(p) {
                if let Some(r) = p.run(sys, OOO, c) {
                    let e = r.result.energy;
                    t.row(vec![
                        c.to_string(),
                        sys.into(),
                        f(e.l1),
                        f(e.l2),
                        f(e.l3),
                        f(e.dram),
                        f(e.link),
                        f(e.total()),
                    ]);
                }
            }
        }
        out.push_str(&t.render());
        // Summary ratio (when the sweep includes both paper presets).
        let ratios: Vec<f64> = CORE_SWEEP
            .iter()
            .filter_map(|&c| {
                let h = p.run("host", OOO, c)?.result.energy.total();
                let n = p.run("ndp", OOO, c)?.result.energy.total();
                Some(h / n)
            })
            .collect();
        if !ratios.is_empty() {
            out.push_str(&format!(
                "mean host/NDP energy ratio across core counts: {:.2}x\n",
                geomean(&ratios)
            ));
        }
        out.push('\n');
    }
    out
}

// ----------------------------------------------------------- fig8/fig13

/// AMAT figures (8: class 1b; 13: class 2b).
pub fn fig_amat(reps: &[FunctionProfile], fig: &str, codes: [&str; 2], class: &str) -> String {
    let mut out = String::new();
    for code in codes {
        let Some(p) = by_code(reps, code) else { continue };
        let mut t = Table::new(
            &format!("Fig {fig} — {code} (class {class}): AMAT (cycles) by level"),
            &["cores", "system", "L1", "L2", "L3", "DRAM", "AMAT"],
        );
        for &c in CORE_SWEEP.iter() {
            for sys in system_labels(p) {
                if let Some(r) = p.run(sys, OOO, c) {
                    let a = r.result.amat_parts;
                    t.row(vec![
                        c.to_string(),
                        sys.into(),
                        f(a[0]),
                        f(a[1]),
                        f(a[2]),
                        f(a[3]),
                        f(r.result.amat),
                    ]);
                }
            }
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out
}

// ---------------------------------------------------------------- fig11

/// Fig 11: memory-request breakdown for class-2a functions.
pub fn fig11(reps: &[FunctionProfile]) -> String {
    let mut out = String::new();
    for code in ["PLYGramSch", "SPLFftRev"] {
        let Some(p) = by_code(reps, code) else { continue };
        let mut t = Table::new(
            &format!("Fig 11 — {code}: host loads serviced per level (%)"),
            &["cores", "L1", "L2", "L3", "DRAM", "ctrl-utilization"],
        );
        for &c in CORE_SWEEP.iter() {
            if let Some(r) = p.run("host", OOO, c) {
                let fr = r.result.level_fracs;
                t.row(vec![
                    c.to_string(),
                    f(fr[0] * 100.0),
                    f(fr[1] * 100.0),
                    f(fr[2] * 100.0),
                    f(fr[3] * 100.0),
                    f(r.result.dram_rho),
                ]);
            }
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out.push_str("Paper shape: DRAM share explodes at high core counts (cache contention).\n");
    out
}

// ------------------------------------------------------------ fig16/17

/// Fig 16: performance with the NUCA (2 MiB/core) L3 vs fixed 8 MiB vs NDP.
pub fn fig16(reps: &[FunctionProfile]) -> String {
    let mut out = String::new();
    for (code, class) in FIG5_FUNCTIONS {
        let Some(p) = by_code(reps, code) else { continue };
        if p.run("host-nuca", OOO, 1).is_none() {
            continue;
        }
        let mut t = Table::new(
            &format!("Fig 16 — {code} (class {class}): normalized perf, LLC-size sweep"),
            &["cores", "host-8MB", "host-NUCA", "ndp"],
        );
        for &c in CORE_SWEEP.iter() {
            t.row(vec![
                c.to_string(),
                f(p.norm_perf("host", OOO, c)),
                f(p.norm_perf("host-nuca", OOO, c)),
                f(p.norm_perf("ndp", OOO, c)),
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out
}

/// Fig 17: energy with the NUCA L3 vs fixed 8 MiB vs NDP.
pub fn fig17(reps: &[FunctionProfile]) -> String {
    let mut out = String::new();
    for (code, class) in FIG5_FUNCTIONS {
        let Some(p) = by_code(reps, code) else { continue };
        if p.run("host-nuca", OOO, 1).is_none() {
            continue;
        }
        let mut t = Table::new(
            &format!("Fig 17 — {code} (class {class}): total energy (J)"),
            &["cores", "host-8MB", "host-NUCA", "ndp"],
        );
        for &c in CORE_SWEEP.iter() {
            let e = |sys: &str| {
                p.run(sys, OOO, c)
                    .map(|r| r.result.energy.total())
                    .unwrap_or(f64::NAN)
            };
            t.row(vec![
                c.to_string(),
                f(e("host")),
                f(e("host-nuca")),
                f(e("ndp")),
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out
}

// ---------------------------------------------------------------- fig18

/// Fig 18: distribution of metrics and NDP speedups per class, for both
/// core models, over all supplied functions (reps + holdout = 144).
pub fn fig18(all: &[FunctionProfile]) -> String {
    let mut out = String::new();
    let class_of = |p: &FunctionProfile| p.paper_class.unwrap_or(p.family_class);

    let mut t = Table::new(
        "Fig 18a: key metric distributions per class (all functions)",
        &["class", "metric", "distribution"],
    );
    for class in ["1a", "1b", "1c", "2a", "2b", "2c"] {
        let sel: Vec<&FunctionProfile> =
            all.iter().filter(|p| class_of(p) == class).collect();
        if sel.is_empty() {
            continue;
        }
        let dist = |vals: Vec<f64>| Summary::of(&vals).map(|s| s.render()).unwrap_or_default();
        t.row(vec![
            class.into(),
            "temporal".into(),
            dist(sel.iter().map(|p| p.locality.temporal).collect()),
        ]);
        t.row(vec![
            class.into(),
            "AI".into(),
            dist(sel.iter().map(|p| p.ai).collect()),
        ]);
        t.row(vec![
            class.into(),
            "MPKI".into(),
            dist(sel.iter().map(|p| p.mpki).collect()),
        ]);
        t.row(vec![
            class.into(),
            "LFMR".into(),
            dist(sel.iter().map(|p| p.lfmr_mean()).collect()),
        ]);
    }
    out.push_str(&t.render());
    out.push('\n');

    let mut t2 = Table::new(
        "Fig 18b: NDP speedup per class and core model (mean over cores & functions)",
        &["class", "model", "mean", "max", "paper-mean(ooo/io)"],
    );
    let paper_means = [
        ("1a", "1.59 / 1.77"),
        ("1b", "1.22 / 1.15"),
        ("1c", "0.96 / 0.95"),
        ("2a", "1.04 / 1.22"),
        ("2b", "0.94 / 1.01"),
        ("2c", "0.56 / 0.76"),
    ];
    for (class, paper) in paper_means {
        for model in [CoreModel::OutOfOrder, CoreModel::InOrder] {
            let mut speeds = Vec::new();
            for p in all.iter().filter(|p| class_of(p) == class) {
                for &c in CORE_SWEEP.iter() {
                    let s = p.ndp_speedup(model, c);
                    if s.is_finite() {
                        speeds.push(s);
                    }
                }
            }
            if speeds.is_empty() {
                continue;
            }
            let max = speeds.iter().copied().fold(f64::MIN, f64::max);
            t2.row(vec![
                class.into(),
                if model == OOO { "ooo" } else { "inorder" }.into(),
                f(geomean(&speeds)),
                f(max),
                paper.into(),
            ]);
        }
    }
    out.push_str(&t2.render());
    out
}

// ---------------------------------------------------------------- fig19

/// Fig 19: hierarchical-clustering dendrogram over the classification
/// features of the 44 representatives.
pub fn fig19(reps: &[FunctionProfile]) -> String {
    let mut rows: Vec<Vec<f64>> = reps
        .iter()
        .map(|p| {
            let ft = Features::of(p);
            vec![ft.temporal, ft.mpki, ft.lfmr, ft.ai, ft.slope]
        })
        .collect();
    crate::util::stats::normalize_columns(&mut rows);
    let merges = cluster::hierarchical(&rows);
    let labels: Vec<String> = reps
        .iter()
        .map(|p| format!("{}({})", p.code, p.paper_class.unwrap_or("?")))
        .collect();
    let mut out =
        String::from("Fig 19: hierarchical clustering (average linkage, normalized features)\n\n");
    out.push_str(&cluster::render_dendrogram(&labels, &merges));
    out
}

// ----------------------------------------------------- case studies 1-4

/// Fig 20 + 21 (case study 1): NDP inter-vault NoC overhead and hop
/// distribution. Fresh simulations with the mesh model enabled.
pub fn fig20_21(scale: Scale) -> String {
    let mut t = Table::new(
        "Fig 20: NDP interconnect overhead (16 NDP cores, 6x6 mesh)",
        &["function", "ideal perf", "mesh perf", "overhead %", "mean hops", "vault imbalance"],
    );
    let mut hops_out = String::new();
    for code in [
        "STRTriad", "HSJNPO", "LIGPrkEmd", "CHAHsti", "PLYGramSch", "SPLLucb", "SPLFftRev",
        "SPLOcpSlave",
    ] {
        let Some(spec) = registry::by_code(code) else { continue };
        let cfg = SystemConfig::ndp(16, OOO);
        let trace = spec.trace(16, scale);
        let ideal = simulate(&cfg, &trace);
        let mesh = simulate_opt(&cfg, &trace, SimOptions { ndp_mesh: true });
        let overhead = (ideal.perf() / mesh.perf() - 1.0) * 100.0;
        t.row(vec![
            code.into(),
            f(ideal.perf()),
            f(mesh.perf()),
            f(overhead),
            f(mesh.noc_mean_hops),
            f(mesh.vault_imbalance),
        ]);
        // Fig 21: hop distribution.
        let total: u64 = mesh.hop_hist.iter().sum();
        if total > 0 {
            hops_out.push_str(&format!("{code:12} hops: "));
            for (h, &cnt) in mesh.hop_hist.iter().enumerate() {
                let pct = cnt as f64 / total as f64 * 100.0;
                if pct >= 0.5 {
                    hops_out.push_str(&format!("{h}:{pct:.0}% "));
                }
            }
            hops_out.push('\n');
        }
    }
    let mut out = t.render();
    out.push_str("\nFig 21: distribution of NoC hops per memory request\n");
    out.push_str(&hops_out);
    out.push_str("\nPaper shape: ~40% of requests travel 3-4 hops; <5% are vault-local.\n");
    out
}

/// Fig 22 (case study 2): NDP accelerator vs compute-centric accelerator.
pub fn fig22() -> String {
    let mut t = Table::new(
        "Fig 22: NDP accelerator speedup over compute-centric accelerator",
        &["function", "class", "speedup", "paper"],
    );
    let sys = SystemConfig::host(1, OOO);
    for (code, paper) in [("DRKYolo", "1.9x"), ("PLYalu", "1.25x"), ("PLY3mm", "1.0x")] {
        let Some(spec) = registry::by_code(code) else { continue };
        let Some(k) = spec.kernel.dataflow() else { continue };
        let s = accel::ndp_speedup(&k, &AccelConfig::default(), &sys);
        t.row(vec![
            code.into(),
            spec.family_class.into(),
            f(s),
            paper.into(),
        ]);
    }
    t.render()
}

/// Fig 23 (case study 3): iso-area/power core models — 4 OoO host cores
/// vs 6 OoO NDP cores vs 128 in-order NDP cores.
pub fn fig23(scale: Scale) -> String {
    let mut t = Table::new(
        "Fig 23: iso-area NDP speedup over 4 OoO host cores",
        &["function", "class", "NDP 6xOoO", "NDP 128xIO", "ratio IO/OoO"],
    );
    for (code, class) in [
        ("STRTriad", "1a"),
        ("DRKYolo", "1a"),
        ("CHAHsti", "1b"),
        ("PLYalu", "1b"),
        ("PLYgemver", "2b"),
        ("SPLLucb", "2b"),
    ] {
        let Some(spec) = registry::by_code(code) else { continue };
        let host = simulate(&SystemConfig::host(4, OOO), &spec.trace(4, scale));
        let ndp_ooo = simulate(&SystemConfig::ndp(6, OOO), &spec.trace(6, scale));
        let ndp_io = simulate(
            &SystemConfig::ndp(128, CoreModel::InOrder),
            &spec.trace(128, scale),
        );
        let s_ooo = ndp_ooo.perf() / host.perf();
        let s_io = ndp_io.perf() / host.perf();
        t.row(vec![
            code.into(),
            class.into(),
            f(s_ooo),
            f(s_io),
            f(s_io / s_ooo),
        ]);
    }
    let mut out = t.render();
    out.push_str("\nPaper shape: 128 in-order NDP cores beat 6 OoO NDP cores (~4x on average),\nbut by less than the 21x core-count ratio (static scheduling limits).\n");
    out
}

/// Fig 24 + 25 (case study 4): basic-block LLC-miss concentration and
/// fine-grained (hottest-bb) offload speedup vs whole-function offload.
pub fn fig24_25(reps: &[FunctionProfile]) -> String {
    let mut t = Table::new(
        "Fig 24: LLC-miss share of the hottest basic block (host, 4 cores)",
        &["function", "class", "#bbs", "hottest bb", "miss share %"],
    );
    let mut t25 = Table::new(
        "Fig 25: speedup of offloading hottest bb vs whole function (64 cores)",
        &["function", "bb offload", "whole function", "paper"],
    );
    for (code, paper_note) in [
        ("LIGKcrEms", "~1.25x vs ~1.5x"),
        ("HSJPRH", "bb covers most misses"),
        ("DRKRes", "bb covers most misses"),
    ] {
        let Some(p) = by_code(reps, code) else { continue };
        let Some(r) = p.run("host", OOO, 4) else { continue };
        let bb = &r.result.bb_llc_misses;
        let total: u64 = bb.iter().sum();
        let n_bbs = bb.iter().filter(|&&c| c > 0).count();
        let (hot_bb, &hot) = bb
            .iter()
            .enumerate()
            .max_by_key(|(_, &c)| c)
            .unwrap_or((0, &0));
        let share = hot as f64 / total.max(1) as f64;
        t.row(vec![
            code.into(),
            p.paper_class.unwrap_or("?").into(),
            n_bbs.to_string(),
            format!("bb{hot_bb}"),
            f(share * 100.0),
        ]);
        // Fig 25 model: whole-function offload achieves the measured NDP
        // speedup; offloading only the hottest bb captures its share of
        // the DRAM-stall reduction (Amdahl over the miss share).
        let whole = p.ndp_speedup(OOO, 64);
        if whole.is_finite() && whole > 1.0 {
            let gain_fraction = share;
            let bb_speedup = 1.0 / ((1.0 - gain_fraction) + gain_fraction / whole);
            t25.row(vec![
                code.into(),
                f(bb_speedup),
                f(whole),
                paper_note.into(),
            ]);
        }
    }
    let mut out = t.render();
    out.push('\n');
    out.push_str(&t25.render());
    out.push_str("\nPaper shape: 1-10% of basic blocks produce up to 95% of LLC misses;\nhottest-bb offload recovers roughly half the whole-function speedup.\n");
    out
}

// ----------------------------------------------------------- tab8 / val

// ---------------------------------------------------------------- health

/// Sweep health: coverage of a profile set against the spec list it was
/// meant to cover. A fault-free complete sweep reports 100%; after a
/// degraded run (worker failures, interrupted sweep, watchdog timeouts)
/// this names exactly which functions are missing — and how many of
/// those hit the job timeout — so a `--resume` run can finish the job.
pub fn sweep_health(
    expected: &[crate::workloads::FunctionSpec],
    profiles: &[FunctionProfile],
    retryable: &[crate::coordinator::store::RetryableRecord],
) -> String {
    use std::collections::{BTreeMap, BTreeSet};
    let have: BTreeSet<String> = profiles.iter().map(|p| p.code.clone()).collect();
    let timed_out: BTreeSet<&str> = retryable
        .iter()
        .filter(|r| r.kind == "timed-out" && !have.contains(&r.code))
        .map(|r| r.code.as_str())
        .collect();
    let mut by_class: BTreeMap<&str, (usize, usize, usize, Vec<String>)> = BTreeMap::new();
    for s in expected {
        let class = s.paper_class.unwrap_or(s.family_class);
        let entry = by_class.entry(class).or_default();
        entry.0 += 1;
        let code = s.id.code();
        if have.contains(&code) {
            entry.1 += 1;
        } else {
            if timed_out.contains(code.as_str()) {
                entry.2 += 1;
            }
            entry.3.push(code);
        }
    }
    let mut t = Table::new(
        "Sweep health: profile coverage per class",
        &["class", "expected", "present", "timed-out", "missing"],
    );
    for (class, (exp, present, n_timeout, missing)) in &by_class {
        t.row(vec![
            class.to_string(),
            exp.to_string(),
            present.to_string(),
            n_timeout.to_string(),
            if missing.is_empty() {
                "-".to_string()
            } else {
                missing.join(" ")
            },
        ]);
    }
    let total_missing: usize = by_class.values().map(|v| v.3.len()).sum();
    let total_timeouts: usize = by_class.values().map(|v| v.2).sum();
    let mut out = t.render();
    out.push_str(&format!(
        "\n{}/{} functions profiled{}\n",
        expected.len() - total_missing,
        expected.len(),
        if total_missing == 0 {
            "; sweep complete".to_string()
        } else {
            format!("; rerun with --resume to finish the remaining {total_missing}")
        }
    ));
    if total_timeouts > 0 {
        out.push_str(&format!(
            "{total_timeouts} of the missing functions hit the job timeout; \
             raise --job-timeout if they keep timing out on --resume\n"
        ));
    }
    out
}

/// Telemetry snapshot: every registered counter, gauge and histogram of
/// this process. After a sweep this covers sim throughput, cache/DRAM
/// totals, retries, injected faults, checkpoint flushes, and span
/// durations; on `--resume` the counts are cumulative across the
/// interrupted run (absorbed from the checkpoint's metrics snapshot).
pub fn telemetry_report() -> String {
    let mut out = String::from("Telemetry: metrics snapshot (see docs/telemetry.md)\n\n");
    out.push_str(&crate::util::telemetry::metrics::render_text());
    out
}

/// Table 8 / Appendix A: the full function list with classes.
pub fn tab8(reps: &[FunctionProfile], holdout: &[FunctionProfile]) -> String {
    let mut t = Table::new(
        "Table 8 / Appendix A: DAMOV benchmark suite",
        &["suite", "function", "input", "class", "rep?", "temporal", "MPKI", "LFMR", "AI"],
    );
    for p in reps.iter().chain(holdout) {
        t.row(vec![
            p.suite.clone(),
            p.code.clone(),
            p.input.clone(),
            p.paper_class.unwrap_or(p.family_class).into(),
            if p.representative { "yes" } else { "no" }.into(),
            f(p.locality.temporal),
            f(p.mpki),
            f(p.lfmr_mean()),
            f(p.ai),
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "\n{} representative + {} held-out = {} functions\n",
        reps.len(),
        holdout.len(),
        reps.len() + holdout.len()
    ));
    out
}

/// §3.5 validation: threshold derivation + held-out accuracy.
pub fn validation(reps: &[FunctionProfile], holdout: &[FunctionProfile]) -> String {
    let report = classify::validate(reps, holdout);
    let t = report.thresholds;
    let mut out = String::from("§3.5 validation of the classification methodology\n\n");
    out.push_str(&format!(
        "Phase 1 thresholds (paper: temporal 0.48, AI 8.5, MPKI 11.0, LFMR 0.56):\n\
         temporal={:.3}  AI={:.2}  MPKI={:.2}  LFMR={:.3}  slope_dec={:.3}  slope_inc={:.3}\n\n",
        t.temporal, t.ai, t.mpki, t.lfmr, t.slope_dec, t.slope_inc
    ));
    out.push_str(&format!(
        "Phase 2 held-out accuracy: {}/{} = {:.1}% (paper: 97%)\n",
        report.correct,
        report.total,
        report.accuracy() * 100.0
    ));
    if !report.errors.is_empty() {
        out.push_str("\nMisclassified functions:\n");
        for (code, exp, got) in &report.errors {
            out.push_str(&format!(
                "  {code}: expected {}, got {}\n",
                exp.label(),
                got.label()
            ));
        }
    }
    out.push_str(
        "\nConfusion matrix (rows = expected, cols = predicted):\n      1a   1b   1c   2a   2b   2c\n",
    );
    for (i, c) in classify::ALL_CLASSES.iter().enumerate() {
        out.push_str(&format!("{:>4}", c.label()));
        for jv in report.confusion[i] {
            out.push_str(&format!("{jv:5}"));
        }
        out.push('\n');
    }
    // Also classify the representatives with their own thresholds
    // (self-consistency).
    let self_correct = reps
        .iter()
        .filter(|p| {
            p.paper_class
                .and_then(Class::parse)
                .map(|expected| {
                    classify::classify(&Features::of(p), &report.thresholds) == expected
                })
                .unwrap_or(false)
        })
        .count();
    out.push_str(&format!(
        "\nSelf-consistency on the 44 representatives: {}/{}\n",
        self_correct,
        reps.len()
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::methodology::step3::{profile_function, SweepOptions};

    fn mini_profiles() -> Vec<FunctionProfile> {
        ["STRCpy", "CHAHsti"]
            .iter()
            .map(|c| {
                profile_function(
                    &registry::by_code(c).unwrap(),
                    SweepOptions {
                        scale: Scale(0.05),
                        ..Default::default()
                    },
                )
            })
            .collect()
    }

    #[test]
    fn tab1_mentions_key_parameters() {
        let s = tab1();
        assert!(s.contains("115 GB/s"));
        assert!(s.contains("431 GB/s"));
        assert!(s.contains("HMC"));
    }

    #[test]
    fn fig1_renders_rows_for_each_profile() {
        let profiles = mini_profiles();
        let s = fig1(&profiles);
        assert!(s.contains("STRCpy"));
        assert!(s.contains("CHAHsti"));
    }

    #[test]
    fn fig5_skips_missing_functions() {
        let profiles = mini_profiles();
        let s = fig5(&profiles);
        // None of the 12 deep-dive codes are in mini_profiles; header-free.
        assert!(!s.contains("STRCpy"));
    }

    #[test]
    fn fig18_contains_all_present_classes() {
        let profiles = mini_profiles();
        let s = fig18(&profiles);
        assert!(s.contains("1a"));
        assert!(s.contains("1b"));
    }

    #[test]
    fn sweep_health_reports_missing_functions() {
        let profiles = mini_profiles(); // STRCpy + CHAHsti
        let specs: Vec<_> = ["STRCpy", "CHAHsti", "STRTriad"]
            .iter()
            .map(|c| registry::by_code(c).unwrap())
            .collect();
        let s = sweep_health(&specs, &profiles, &[]);
        assert!(s.contains("STRTriad"), "missing function must be named:\n{s}");
        assert!(s.contains("2/3 functions profiled"));
        assert!(s.contains("--resume"));
        let complete = sweep_health(&specs[..2], &profiles, &[]);
        assert!(complete.contains("sweep complete"));
    }

    #[test]
    fn sweep_health_counts_timed_out_functions() {
        let profiles = mini_profiles(); // STRCpy + CHAHsti
        let specs: Vec<_> = ["STRCpy", "CHAHsti", "STRTriad"]
            .iter()
            .map(|c| registry::by_code(c).unwrap())
            .collect();
        let retryable = vec![
            crate::coordinator::store::RetryableRecord {
                code: "STRTriad".to_string(),
                kind: "timed-out".to_string(),
                attempts: 1,
                message: "job timeout".to_string(),
            },
            // A stale record for a function that later completed must
            // not count: the profile supersedes it.
            crate::coordinator::store::RetryableRecord {
                code: "STRCpy".to_string(),
                kind: "timed-out".to_string(),
                attempts: 1,
                message: "job timeout".to_string(),
            },
        ];
        let s = sweep_health(&specs, &profiles, &retryable);
        assert!(s.contains("1 of the missing functions hit the job timeout"), "{s}");
        assert!(s.contains("--job-timeout"));
        assert!(s.contains("rerun with --resume"));
    }

    #[test]
    fn fig22_has_three_rows() {
        let s = fig22();
        assert!(s.contains("DRKYolo"));
        assert!(s.contains("PLYalu"));
        assert!(s.contains("PLY3mm"));
    }

    #[test]
    fn validation_renders_with_mini_sets() {
        let profiles = mini_profiles();
        let s = validation(&profiles, &profiles);
        assert!(s.contains("Phase 2 held-out accuracy"));
        assert!(s.contains("Confusion matrix"));
    }
}
