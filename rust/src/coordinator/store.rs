//! Results store: JSON (de)serialization of function profiles.
//!
//! The full characterization sweep takes minutes; persisting profiles
//! lets `damov report <fig>` regenerate any figure instantly and gives
//! downstream users a machine-readable results database.
//!
//! ## Durability model
//!
//! Two on-disk artifacts, both versioned ([`SCHEMA_VERSION`]) and keyed
//! by a sweep *fingerprint* (hash of spec codes + sweep options, see
//! `coordinator::sweep_fingerprint`) with a per-record FNV-64 checksum
//! over the canonical serialization:
//!
//! * **Cache** (`profiles-<tag>.json`): the complete result set, written
//!   via temp-file + atomic rename — an interrupted save can never leave
//!   a torn file that poisons the next run.
//! * **Checkpoint** (`checkpoint-<tag>.jsonl`): append-only JSON-lines
//!   (header line + one record per completed function, flushed per
//!   record). A crash or Ctrl-C mid-sweep loses at most the record being
//!   written; `--resume` replays the intact prefix and recomputes only
//!   the rest. A torn tail is detected (parse/checksum failure) and
//!   dropped.
//!
//! Legacy bare-array files (schema v1) are still readable through
//! [`load_profiles`]; the fingerprint-checked [`load_profiles_keyed`]
//! rejects them, forcing one clean recompute.

use crate::methodology::locality::LocalityMetrics;
use crate::methodology::step3::{FunctionProfile, Run};
use crate::sim::engine::SimResult;
use crate::sim::CoreModel;
use crate::util::fault;
use crate::util::json::Json;
use crate::util::telemetry::{self, metrics};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Version of the persisted document schema, written into every new
/// header. Bump on any change to the document structure. v3 added
/// *retryable* failure lines to checkpoints
/// ([`CheckpointWriter::append_retryable`]); v4 switched run records
/// from the closed system-kind enum to open spec names (the `"kind"`
/// key is retained and the four preset labels are byte-identical, so
/// v2/v3 documents still load — see [`schema_compatible`]) and folded
/// the per-spec fingerprint into the sweep fingerprint.
pub const SCHEMA_VERSION: u64 = 4;

/// Version of the per-profile record layout, part of the sweep
/// fingerprint (see `coordinator::sweep_fingerprint`). Unchanged since
/// schema v2 — v3 only added new line kinds, v4 only widened the set of
/// accepted `"kind"` values — so fingerprints (and with them caches and
/// checkpoints) remain stable across the v2→v4 bumps. Bump this, not
/// just [`SCHEMA_VERSION`], when the record layout itself changes.
pub const RECORD_VERSION: u64 = 2;

/// Document versions this build can read: v2 (profiles + metrics
/// lines), v3 (adds retryable lines) and v4 (open system names).
fn schema_compatible(schema: u64) -> bool {
    (2..=SCHEMA_VERSION).contains(&schema)
}

fn model_label(m: CoreModel) -> &'static str {
    match m {
        CoreModel::OutOfOrder => "ooo",
        CoreModel::InOrder => "inorder",
    }
}

fn model_parse(s: &str) -> Option<CoreModel> {
    match s {
        "ooo" => Some(CoreModel::OutOfOrder),
        "inorder" => Some(CoreModel::InOrder),
        _ => None,
    }
}

fn f64s(j: &Json, key: &str) -> f64 {
    j.get(key).and_then(Json::as_f64).unwrap_or(f64::NAN)
}

fn u64s(j: &Json, key: &str) -> u64 {
    j.get(key).and_then(Json::as_f64).unwrap_or(0.0) as u64
}

fn arr_f64(j: &Json, key: &str) -> Vec<f64> {
    j.get(key)
        .and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(Json::as_f64).collect())
        .unwrap_or_default()
}

fn sim_to_json(r: &SimResult) -> Json {
    let mut j = Json::obj();
    j.set("time_s", r.time_s)
        .set("cycles", r.cycles)
        .set("instr", r.instr)
        .set("ipc", r.ipc)
        .set("memory_bound", r.memory_bound)
        .set("l1_hits", r.l1_hits)
        .set("l1_misses", r.l1_misses)
        .set("l2_hits", r.l2_hits)
        .set("l2_misses", r.l2_misses)
        .set("l3_hits", r.l3_hits)
        .set("l3_misses", r.l3_misses)
        .set("mpki", r.mpki)
        .set("lfmr", r.lfmr)
        .set("ai", r.ai)
        .set("amat", r.amat)
        .set("amat_parts", r.amat_parts.to_vec())
        .set("level_fracs", r.level_fracs.to_vec())
        .set("dram_reads", r.dram_reads)
        .set("dram_writes", r.dram_writes)
        .set("row_hit_rate", r.row_hit_rate)
        .set("bw", r.bw_bytes_s)
        .set("rho", r.dram_rho)
        .set("dram_loaded_lat", r.dram_loaded_lat)
        .set("vault_imbalance", r.vault_imbalance)
        .set("pf_issued", r.pf_issued)
        .set("pf_accuracy", r.pf_accuracy)
        .set("noc_mean_hops", r.noc_mean_hops)
        .set(
            "hop_hist",
            r.hop_hist.iter().map(|&x| x as f64).collect::<Vec<f64>>(),
        )
        .set(
            "bb_llc",
            // Store only nonzero entries as [bb, count] pairs.
            Json::Arr(
                r.bb_llc_misses
                    .iter()
                    .enumerate()
                    .filter(|(_, &c)| c > 0)
                    .map(|(bb, &c)| Json::Arr(vec![Json::Num(bb as f64), Json::Num(c as f64)]))
                    .collect(),
            ),
        )
        .set("e_l1", r.energy.l1)
        .set("e_l2", r.energy.l2)
        .set("e_l3", r.energy.l3)
        .set("e_dram", r.energy.dram)
        .set("e_link", r.energy.link)
        .set("e_noc", r.energy.noc);
    j
}

fn sim_from_json(system: String, core_model: CoreModel, cores: usize, j: &Json) -> SimResult {
    let mut bb = vec![0u64; 256];
    if let Some(pairs) = j.get("bb_llc").and_then(Json::as_arr) {
        for p in pairs {
            if let Some(pair) = p.as_arr() {
                if pair.len() == 2 {
                    let idx = pair[0].as_f64().unwrap_or(0.0) as usize;
                    if idx < 256 {
                        bb[idx] = pair[1].as_f64().unwrap_or(0.0) as u64;
                    }
                }
            }
        }
    }
    let to4 = |v: Vec<f64>| -> [f64; 4] {
        let mut out = [0.0; 4];
        for (i, x) in v.into_iter().take(4).enumerate() {
            out[i] = x;
        }
        out
    };
    SimResult {
        system,
        core_model,
        cores,
        time_s: f64s(j, "time_s"),
        cycles: f64s(j, "cycles"),
        instr: u64s(j, "instr"),
        ipc: f64s(j, "ipc"),
        memory_bound: f64s(j, "memory_bound"),
        l1_hits: u64s(j, "l1_hits"),
        l1_misses: u64s(j, "l1_misses"),
        l2_hits: u64s(j, "l2_hits"),
        l2_misses: u64s(j, "l2_misses"),
        l3_hits: u64s(j, "l3_hits"),
        l3_misses: u64s(j, "l3_misses"),
        mpki: f64s(j, "mpki"),
        lfmr: f64s(j, "lfmr"),
        ai: f64s(j, "ai"),
        amat: f64s(j, "amat"),
        amat_parts: to4(arr_f64(j, "amat_parts")),
        level_fracs: to4(arr_f64(j, "level_fracs")),
        dram_reads: u64s(j, "dram_reads"),
        dram_writes: u64s(j, "dram_writes"),
        row_hit_rate: f64s(j, "row_hit_rate"),
        bw_bytes_s: f64s(j, "bw"),
        dram_rho: f64s(j, "rho"),
        dram_loaded_lat: f64s(j, "dram_loaded_lat"),
        vault_imbalance: f64s(j, "vault_imbalance"),
        pf_issued: u64s(j, "pf_issued"),
        pf_accuracy: f64s(j, "pf_accuracy"),
        noc_mean_hops: f64s(j, "noc_mean_hops"),
        hop_hist: arr_f64(j, "hop_hist").into_iter().map(|x| x as u64).collect(),
        bb_llc_misses: bb,
        energy: crate::sim::energy::EnergyBreakdown {
            l1: f64s(j, "e_l1"),
            l2: f64s(j, "e_l2"),
            l3: f64s(j, "e_l3"),
            dram: f64s(j, "e_dram"),
            link: f64s(j, "e_link"),
            noc: f64s(j, "e_noc"),
        },
    }
}

pub fn profile_to_json(p: &FunctionProfile) -> Json {
    let mut j = Json::obj();
    j.set("code", p.code.as_str())
        .set("input", p.input.as_str())
        .set("suite", p.suite.as_str())
        .set("paper_class", p.paper_class.map(Json::from).unwrap_or(Json::Null))
        .set("family_class", p.family_class)
        .set("representative", p.representative)
        .set("spatial", p.locality.spatial)
        .set("temporal", p.locality.temporal)
        .set("windows", p.locality.windows)
        .set("ai", p.ai)
        .set("mpki", p.mpki)
        .set("lfmr", p.lfmr)
        .set("memory_bound", p.memory_bound)
        .set("lfmr_by_cores", p.lfmr_by_cores.clone())
        .set(
            "runs",
            Json::Arr(
                p.runs
                    .iter()
                    .map(|r| {
                        let mut jr = Json::obj();
                        // The JSON key stays `"kind"` for byte-compat
                        // with v2/v3 documents; the value is the open
                        // spec name ("host", "ndp", custom names, ...).
                        jr.set("kind", r.system.as_str())
                            .set("model", model_label(r.core_model))
                            .set("cores", r.cores)
                            .set("result", sim_to_json(&r.result));
                        jr
                    })
                    .collect(),
            ),
        );
    j
}

fn static_class(s: &str) -> Option<&'static str> {
    // Map back onto the static labels used across the crate.
    ["1a", "1b", "1c", "2a", "2b", "2c"]
        .into_iter()
        .find(|&c| c == s)
}

pub fn profile_from_json(j: &Json) -> Option<FunctionProfile> {
    let runs = j
        .get("runs")?
        .as_arr()?
        .iter()
        .filter_map(|jr| {
            let system = jr.get("kind")?.as_str()?.to_string();
            if system.is_empty() {
                return None;
            }
            let model = model_parse(jr.get("model")?.as_str()?)?;
            let cores = jr.get("cores")?.as_f64()? as usize;
            let result = sim_from_json(system.clone(), model, cores, jr.get("result")?);
            Some(Run {
                system,
                core_model: model,
                cores,
                result,
            })
        })
        .collect::<Vec<_>>();
    Some(FunctionProfile {
        code: j.get("code")?.as_str()?.to_string(),
        input: j.get("input")?.as_str()?.to_string(),
        suite: j.get("suite")?.as_str()?.to_string(),
        paper_class: j
            .get("paper_class")
            .and_then(Json::as_str)
            .and_then(static_class),
        family_class: static_class(j.get("family_class")?.as_str()?)?,
        representative: j.get("representative")?.as_bool()?,
        locality: LocalityMetrics {
            spatial: f64s(j, "spatial"),
            temporal: f64s(j, "temporal"),
            windows: u64s(j, "windows") as usize,
        },
        ai: f64s(j, "ai"),
        mpki: f64s(j, "mpki"),
        lfmr: f64s(j, "lfmr"),
        memory_bound: f64s(j, "memory_bound"),
        lfmr_by_cores: arr_f64(j, "lfmr_by_cores"),
        runs,
    })
}

/// FNV-1a 64 over a canonical serialization, hex-encoded. Stored as a
/// string because this JSON model keeps numbers as f64 (u64 checksums
/// would lose bits above 2^53).
fn checksum_hex(s: &str) -> String {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    format!("{h:016x}")
}

/// One checksummed profile record.
fn record_to_json(p: &FunctionProfile) -> Json {
    let pj = profile_to_json(p);
    let sum = checksum_hex(&pj.to_string_compact());
    let mut j = Json::obj();
    j.set("checksum", sum).set("profile", pj);
    j
}

/// Decode + verify one record. The checksum is recomputed over the
/// re-serialized parsed value; serialization is canonical (ordered keys,
/// deterministic float formatting), so any corruption of the stored
/// profile — even one that still parses — is caught.
fn record_from_json(j: &Json) -> Option<FunctionProfile> {
    let sum = j.get("checksum")?.as_str()?;
    let pj = j.get("profile")?;
    if checksum_hex(&pj.to_string_compact()) != sum {
        return None;
    }
    profile_from_json(pj)
}

/// Write `text` to `path` via a temp file + atomic rename, so readers
/// never observe a partially written file.
fn write_atomic(path: &Path, text: &str) -> std::io::Result<()> {
    let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
    std::fs::write(&tmp, text)?;
    match std::fs::rename(&tmp, path) {
        Ok(()) => Ok(()),
        Err(e) => {
            std::fs::remove_file(&tmp).ok();
            Err(e)
        }
    }
}

/// Persist the complete result set of a sweep, keyed by its fingerprint.
pub fn save_profiles_keyed(
    path: &Path,
    profiles: &[FunctionProfile],
    fingerprint: &str,
) -> std::io::Result<()> {
    let _span = telemetry::span("store.save");
    fault::maybe_io("store", fault::key_of(&path.to_string_lossy()))?;
    let mut root = Json::obj();
    root.set("schema", SCHEMA_VERSION)
        .set("fingerprint", fingerprint)
        .set(
            "records",
            Json::Arr(profiles.iter().map(record_to_json).collect()),
        );
    write_atomic(path, &root.to_string_pretty())?;
    metrics::counter("store.cache_saves").incr();
    Ok(())
}

/// [`save_profiles_keyed`] with an empty fingerprint (ad-hoc dumps).
pub fn save_profiles(path: &Path, profiles: &[FunctionProfile]) -> std::io::Result<()> {
    save_profiles_keyed(path, profiles, "")
}

/// Decode a keyed (schema v2/v3) document; `None` on any
/// version/record mismatch.
fn parse_v2(j: &Json) -> Option<(String, Vec<FunctionProfile>)> {
    let schema = j.get("schema")?.as_f64()? as u64;
    if !schema_compatible(schema) {
        return None;
    }
    let fp = j.get("fingerprint")?.as_str()?.to_string();
    let records = j.get("records")?.as_arr()?;
    let profiles: Vec<FunctionProfile> = records.iter().filter_map(record_from_json).collect();
    if profiles.len() == records.len() {
        Some((fp, profiles))
    } else {
        None // corrupt record: distrust the whole file, recompute
    }
}

/// Load a profile store regardless of its fingerprint: schema-v2
/// documents (checksum-verified) and legacy bare arrays both work.
/// `None` on any corruption — the caller recomputes.
pub fn load_profiles(path: &Path) -> Option<Vec<FunctionProfile>> {
    let text = std::fs::read_to_string(path).ok()?;
    let j = Json::parse(&text).ok()?;
    match &j {
        Json::Obj(_) => parse_v2(&j).map(|(_, profiles)| profiles),
        Json::Arr(arr) => {
            // Legacy (schema v1): bare array of profiles, no checksums.
            let profiles: Vec<FunctionProfile> =
                arr.iter().filter_map(profile_from_json).collect();
            (profiles.len() == arr.len()).then_some(profiles)
        }
        _ => None,
    }
}

/// Load a cache only if it is schema-v2, intact, and was produced by a
/// sweep with exactly this fingerprint. This is what fixes the stale
/// cache bug: a file whose *length* happens to match but whose specs or
/// options differ is rejected instead of silently served.
pub fn load_profiles_keyed(path: &Path, fingerprint: &str) -> Option<Vec<FunctionProfile>> {
    let _span = telemetry::span("store.load");
    let text = std::fs::read_to_string(path).ok()?;
    let j = Json::parse(&text).ok()?;
    let (fp, profiles) = parse_v2(&j)?;
    let hit = (fp == fingerprint).then_some(profiles);
    if hit.is_some() {
        metrics::counter("store.cache_hits").incr();
    }
    hit
}

/// Append-only crash-safe sweep checkpoint (JSON-lines; see module docs).
/// Shared across worker threads; each append holds the file lock just
/// long enough to write + flush one record.
pub struct CheckpointWriter {
    file: Mutex<std::fs::File>,
    path: PathBuf,
}

impl CheckpointWriter {
    /// Start a checkpoint at `path`. With `append` (resume), new records
    /// are added after the existing intact prefix; otherwise the file is
    /// recreated with a fresh header line.
    pub fn create(path: &Path, fingerprint: &str, append: bool) -> std::io::Result<CheckpointWriter> {
        fault::maybe_io("store", fault::key_of(&path.to_string_lossy()))?;
        let file = if append && path.exists() {
            std::fs::OpenOptions::new().append(true).open(path)?
        } else {
            let mut f = std::fs::File::create(path)?;
            let mut hdr = Json::obj();
            hdr.set("schema", SCHEMA_VERSION).set("fingerprint", fingerprint);
            f.write_all(hdr.to_string_compact().as_bytes())?;
            f.write_all(b"\n")?;
            f
        };
        Ok(CheckpointWriter {
            file: Mutex::new(file),
            path: path.to_path_buf(),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one completed profile, flushed immediately: a crash loses
    /// at most the record being written, never an earlier one.
    pub fn append(&self, p: &FunctionProfile) -> std::io::Result<()> {
        let _span = telemetry::span("store.checkpoint_append");
        fault::maybe_io("store", fault::key_of(&p.code))?;
        let line = record_to_json(p).to_string_compact();
        let mut f = self.file.lock().unwrap();
        f.write_all(line.as_bytes())?;
        f.write_all(b"\n")?;
        f.flush()?;
        metrics::counter("store.checkpoint_appends").incr();
        Ok(())
    }

    /// Append a metrics snapshot line (`{"checksum":..,"metrics":{..}}`).
    /// Written after each profile so a crashed sweep still leaves its
    /// cumulative counters behind; [`load_checkpoint`] skips these lines
    /// and [`load_checkpoint_metrics`] returns the newest intact one.
    pub fn append_metrics(&self, snap: &Json) -> std::io::Result<()> {
        let sum = checksum_hex(&snap.to_string_compact());
        let mut j = Json::obj();
        j.set("checksum", sum).set("metrics", snap.clone());
        let line = j.to_string_compact();
        let mut f = self.file.lock().unwrap();
        f.write_all(line.as_bytes())?;
        f.write_all(b"\n")?;
        f.flush()
    }

    /// Append a retryable-failure line
    /// (`{"checksum":..,"retryable":{"code":..,"kind":..,..}}`, schema
    /// v3): the named function did not complete (timed out, cancelled,
    /// or panicked out of retries) and `--resume` should re-run it.
    /// Profile loaders skip these lines; [`load_checkpoint_retryable`]
    /// collects them for the health report.
    pub fn append_retryable(&self, r: &RetryableRecord) -> std::io::Result<()> {
        let line = retryable_to_json(r).to_string_compact();
        let mut f = self.file.lock().unwrap();
        f.write_all(line.as_bytes())?;
        f.write_all(b"\n")?;
        f.flush()?;
        metrics::counter("store.retryable_appends").incr();
        Ok(())
    }
}

/// A function recorded in a checkpoint as failed-but-retryable: it
/// produced no profile (so `--resume` re-runs it), and the record
/// preserves *why* for `damov report health`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RetryableRecord {
    /// Function code (`FunctionId::code()`).
    pub code: String,
    /// Failure kind label: `timed-out`, `cancelled`, or `panicked`
    /// (see `pool::JobErrorKind::label`).
    pub kind: String,
    /// Attempts made before giving up (0 = never started).
    pub attempts: u32,
    /// Last error message.
    pub message: String,
}

fn retryable_to_json(r: &RetryableRecord) -> Json {
    let mut body = Json::obj();
    body.set("code", r.code.as_str())
        .set("kind", r.kind.as_str())
        .set("attempts", r.attempts as u64)
        .set("message", r.message.as_str());
    let sum = checksum_hex(&body.to_string_compact());
    let mut j = Json::obj();
    j.set("checksum", sum).set("retryable", body);
    j
}

/// Decode + verify one retryable line; `None` unless it is a retryable
/// record with an intact checksum.
fn retryable_from_json(j: &Json) -> Option<RetryableRecord> {
    let sum = j.get("checksum")?.as_str()?;
    let body = j.get("retryable")?;
    if checksum_hex(&body.to_string_compact()) != sum {
        return None;
    }
    Some(RetryableRecord {
        code: body.get("code")?.as_str()?.to_string(),
        kind: body.get("kind")?.as_str()?.to_string(),
        attempts: body.get("attempts").and_then(Json::as_f64).unwrap_or(0.0) as u32,
        message: body
            .get("message")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string(),
    })
}

/// The retryable-failure records of a checkpoint with a matching
/// header, newest record per function code (a function that failed in
/// several partial sweeps appears once). Codes that later completed
/// still appear — subtract the loaded profiles to get the outstanding
/// set. Missing file or foreign header → empty.
pub fn load_checkpoint_retryable(path: &Path, fingerprint: &str) -> Vec<RetryableRecord> {
    let Some(body) = checkpoint_body(path, fingerprint) else {
        return Vec::new();
    };
    let mut newest: std::collections::BTreeMap<String, RetryableRecord> =
        std::collections::BTreeMap::new();
    for line in body.lines() {
        if line.trim().is_empty() {
            continue;
        }
        let Ok(j) = Json::parse(line) else { break };
        if j.get("retryable").is_some() {
            let Some(r) = retryable_from_json(&j) else {
                break; // corrupt retryable line: distrust the rest
            };
            newest.insert(r.code.clone(), r);
        }
    }
    newest.into_values().collect()
}

/// Decode + verify one metrics snapshot line; `None` unless the line is
/// a metrics record with an intact checksum.
fn metrics_from_json(j: &Json) -> Option<Json> {
    let sum = j.get("checksum")?.as_str()?;
    let snap = j.get("metrics")?;
    (checksum_hex(&snap.to_string_compact()) == sum).then(|| snap.clone())
}

/// Read a checkpoint's body lines if its header matches (schema +
/// fingerprint). Missing file or foreign header → `None`.
fn checkpoint_body(path: &Path, fingerprint: &str) -> Option<String> {
    let text = std::fs::read_to_string(path).ok()?;
    let mut lines = text.lines();
    let first = lines.next()?;
    let hdr = Json::parse(first).ok()?;
    let schema_ok = hdr
        .get("schema")
        .and_then(Json::as_f64)
        .map(|s| schema_compatible(s as u64))
        .unwrap_or(false);
    let fp_ok = hdr.get("fingerprint").and_then(Json::as_str) == Some(fingerprint);
    (schema_ok && fp_ok).then(|| lines.collect::<Vec<_>>().join("\n"))
}

/// Load every intact record of a checkpoint with a matching header
/// (schema + fingerprint). Missing file or foreign header → empty.
/// Interleaved metrics snapshot lines (see
/// [`CheckpointWriter::append_metrics`]) and retryable-failure lines
/// (see [`CheckpointWriter::append_retryable`]) are verified and
/// skipped.
/// Decoding stops at the first torn or corrupt line: everything before
/// it is checksum-verified and trusted, everything after is dropped and
/// will be recomputed.
pub fn load_checkpoint(path: &Path, fingerprint: &str) -> Vec<FunctionProfile> {
    let Some(body) = checkpoint_body(path, fingerprint) else {
        return Vec::new();
    };
    let mut out = Vec::new();
    for line in body.lines() {
        if line.trim().is_empty() {
            continue;
        }
        let Ok(j) = Json::parse(line) else { break };
        if j.get("metrics").is_some() {
            if metrics_from_json(&j).is_some() {
                continue;
            }
            break; // corrupt metrics line: distrust the rest
        }
        if j.get("retryable").is_some() {
            if retryable_from_json(&j).is_some() {
                continue; // schema v3: failure marker, not a profile
            }
            break; // corrupt retryable line: distrust the rest
        }
        let Some(p) = record_from_json(&j) else { break };
        out.push(p);
    }
    out
}

/// The newest intact metrics snapshot of a checkpoint with a matching
/// header, if any. Used by `--resume` to seed the metrics registry so
/// `damov report telemetry` shows cumulative (not per-run) counts.
pub fn load_checkpoint_metrics(path: &Path, fingerprint: &str) -> Option<Json> {
    let body = checkpoint_body(path, fingerprint)?;
    let mut last = None;
    for line in body.lines() {
        if line.trim().is_empty() {
            continue;
        }
        let Ok(j) = Json::parse(line) else { break };
        if let Some(snap) = metrics_from_json(&j) {
            last = Some(snap);
        }
    }
    last
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::methodology::step3::{profile_function, SweepOptions};
    use crate::workloads::{registry, Scale};

    #[test]
    fn profile_roundtrips_through_json() {
        let spec = registry::by_code("STRCpy").unwrap();
        let p = profile_function(
            &spec,
            SweepOptions {
                scale: Scale(0.05),
                ..Default::default()
            },
        );
        let j = profile_to_json(&p);
        let text = j.to_string_pretty();
        let back = profile_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.code, p.code);
        assert_eq!(back.runs.len(), p.runs.len());
        assert!((back.mpki - p.mpki).abs() < 1e-9);
        assert!((back.locality.temporal - p.locality.temporal).abs() < 1e-9);
        let a = &p.runs[3].result;
        let b = &back.runs[3].result;
        assert!((a.time_s - b.time_s).abs() < 1e-18);
        assert_eq!(a.l3_misses, b.l3_misses);
        assert!((a.energy.total() - b.energy.total()).abs() < 1e-15);
        assert_eq!(a.bb_llc_misses, b.bb_llc_misses);
    }

    #[test]
    fn save_load_file_roundtrip() {
        let spec = registry::by_code("STRSca").unwrap();
        let p = profile_function(
            &spec,
            SweepOptions {
                scale: Scale(0.05),
                ..Default::default()
            },
        );
        let path = std::env::temp_dir().join(format!("damov-store-{}.json", std::process::id()));
        save_profiles(&path, std::slice::from_ref(&p)).unwrap();
        let loaded = load_profiles(&path).unwrap();
        assert_eq!(loaded.len(), 1);
        assert_eq!(loaded[0].code, p.code);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_cache_returns_none() {
        let path = std::env::temp_dir().join(format!("damov-bad-{}.json", std::process::id()));
        std::fs::write(&path, "[{\"code\": 42}]").unwrap();
        assert!(load_profiles(&path).is_none());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn keyed_load_requires_matching_fingerprint() {
        let spec = registry::by_code("STRCpy").unwrap();
        let p = profile_function(
            &spec,
            SweepOptions {
                scale: Scale(0.05),
                ..Default::default()
            },
        );
        let path = std::env::temp_dir().join(format!("damov-keyed-{}.json", std::process::id()));
        save_profiles_keyed(&path, std::slice::from_ref(&p), "fp-aaaa").unwrap();
        assert!(load_profiles_keyed(&path, "fp-aaaa").is_some());
        assert!(load_profiles_keyed(&path, "fp-bbbb").is_none());
        // The unkeyed loader still accepts it (checksums verified).
        assert_eq!(load_profiles(&path).unwrap().len(), 1);
        // No temp file left behind by the atomic write.
        assert!(!path.with_extension(format!("tmp.{}", std::process::id())).exists());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn checkpoint_roundtrip_and_torn_tail() {
        let mk = |code: &str| {
            profile_function(
                &registry::by_code(code).unwrap(),
                SweepOptions {
                    scale: Scale(0.05),
                    ..Default::default()
                },
            )
        };
        let a = mk("STRCpy");
        let b = mk("STRSca");
        let path = std::env::temp_dir().join(format!("damov-ckpt-{}.jsonl", std::process::id()));
        let w = CheckpointWriter::create(&path, "fp-1", false).unwrap();
        w.append(&a).unwrap();
        w.append(&b).unwrap();
        drop(w);
        // Simulate a crash mid-append: torn trailing line.
        {
            use std::io::Write as _;
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(b"{\"checksum\":\"00\",\"profile\":{\"co").unwrap();
        }
        let got = load_checkpoint(&path, "fp-1");
        assert_eq!(got.len(), 2, "intact prefix survives a torn tail");
        assert_eq!(got[0].code, a.code);
        assert_eq!(got[1].code, b.code);
        // Foreign fingerprint or missing file → empty.
        assert!(load_checkpoint(&path, "fp-2").is_empty());
        assert!(load_checkpoint(Path::new("/nonexistent/ckpt.jsonl"), "fp-1").is_empty());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn retryable_records_roundtrip_and_are_skipped_by_profile_loads() {
        let p = profile_function(
            &registry::by_code("STRCpy").unwrap(),
            SweepOptions {
                scale: Scale(0.05),
                ..Default::default()
            },
        );
        let path =
            std::env::temp_dir().join(format!("damov-retry-{}.jsonl", std::process::id()));
        let w = CheckpointWriter::create(&path, "fp-r", false).unwrap();
        let rec = RetryableRecord {
            code: "STRSca".to_string(),
            kind: "timed-out".to_string(),
            attempts: 1,
            message: "damov-job-cancelled: job-timeout".to_string(),
        };
        w.append_retryable(&rec).unwrap();
        w.append(&p).unwrap();
        // A later sweep re-fails the same code: newest record wins.
        let rec2 = RetryableRecord {
            kind: "cancelled".to_string(),
            ..rec.clone()
        };
        w.append_retryable(&rec2).unwrap();
        drop(w);
        // Profile loads skip the retryable lines (no torn-tail break).
        let profiles = load_checkpoint(&path, "fp-r");
        assert_eq!(profiles.len(), 1);
        assert_eq!(profiles[0].code, p.code);
        // Retryable load dedupes by code, keeping the newest.
        let retry = load_checkpoint_retryable(&path, "fp-r");
        assert_eq!(retry.len(), 1);
        assert_eq!(retry[0], rec2);
        // Foreign fingerprint → empty.
        assert!(load_checkpoint_retryable(&path, "fp-x").is_empty());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v2_headers_remain_readable_after_v3_bump() {
        let p = profile_function(
            &registry::by_code("STRCpy").unwrap(),
            SweepOptions {
                scale: Scale(0.05),
                ..Default::default()
            },
        );
        // Checkpoint written by a v2-era build: v2 header + profile line.
        let path = std::env::temp_dir().join(format!("damov-v2-{}.jsonl", std::process::id()));
        {
            use std::io::Write as _;
            let mut f = std::fs::File::create(&path).unwrap();
            let mut hdr = Json::obj();
            hdr.set("schema", 2u64).set("fingerprint", "fp-old");
            writeln!(f, "{}", hdr.to_string_compact()).unwrap();
            writeln!(f, "{}", record_to_json(&p).to_string_compact()).unwrap();
        }
        let got = load_checkpoint(&path, "fp-old");
        assert_eq!(got.len(), 1, "v2 checkpoints must stay resumable");
        assert_eq!(got[0].code, p.code);
        std::fs::remove_file(&path).ok();

        // Cache document with a v2 schema field.
        let cache = std::env::temp_dir().join(format!("damov-v2c-{}.json", std::process::id()));
        {
            let mut root = Json::obj();
            root.set("schema", 2u64).set("fingerprint", "fp-old").set(
                "records",
                Json::Arr(vec![record_to_json(&p)]),
            );
            std::fs::write(&cache, root.to_string_pretty()).unwrap();
        }
        assert_eq!(load_profiles_keyed(&cache, "fp-old").unwrap().len(), 1);
        // Unknown future schema is still rejected.
        {
            let mut root = Json::obj();
            root.set("schema", 99u64).set("fingerprint", "fp-old").set(
                "records",
                Json::Arr(vec![record_to_json(&p)]),
            );
            std::fs::write(&cache, root.to_string_pretty()).unwrap();
        }
        assert!(load_profiles_keyed(&cache, "fp-old").is_none());
        std::fs::remove_file(&cache).ok();
    }
}
