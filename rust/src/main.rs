//! `damov` — CLI for the DAMOV reproduction.
//!
//! Commands:
//!   damov list                          list the 144 suite functions
//!   damov config                        print Table 1
//!   damov sim --code C [...]            simulate one function on one system
//!   damov characterize --code C         run the 3-step methodology on one function
//!   damov report <id>|all [...]         regenerate paper tables/figures
//!   damov validate                      §3.5 two-phase validation
//!   damov bench [...]                   time the sweep phases serial vs
//!                                       parallel, emit BENCH_sweep.json
//!   damov systems [name]                list system presets / dump one
//!                                       as spec JSON (docs/systems.md)
//!
//! Common options: --threads N, --scale X, --refresh, --results DIR,
//! --cores N, --system <preset|file.json>, --inorder. Sweep commands
//! also take --systems a,b,c — a comma-separated list of presets and/or
//! spec-JSON paths to sweep instead of the paper's four systems.
//!
//! Robustness options (sweep commands):
//!   --resume            resume an interrupted sweep from its checkpoint
//!                       (`checkpoint-<tag>.jsonl` in the results dir):
//!                       only functions without an intact checkpoint
//!                       record are recomputed
//!   --max-retries N     retries per panicking worker job before it is
//!                       recorded as failed (default 2)
//!   --job-timeout D     soft-cancel any single function taking longer
//!                       than D (e.g. `2s`, `500ms`, `1m`); timed-out
//!                       functions are recorded as retryable in the
//!                       checkpoint and re-run on `--resume`
//!   --sweep-deadline D  wall-clock budget for the whole sweep: when it
//!                       expires, in-flight jobs are cancelled and
//!                       queued jobs are drained, all retryable
//!   --limit N           only sweep the first N representatives (CI
//!                       smoke runs; 0 = no limit, the default)
//!
//! Sweeps persist incrementally: each completed function is appended to
//! a checksummed, crash-safe checkpoint, and the final cache
//! (`profiles-<tag>.json`) is written atomically and keyed by a
//! fingerprint of the specs + sweep options, so stale or torn files are
//! rejected and recomputed, never silently served.
//!
//! Fault injection (testing the above): set `DAMOV_FAULT_SPEC`, e.g.
//! `DAMOV_FAULT_SPEC=panic:0.05,io:0.1,delay:0.2,hang:0.1,seed:42`, to
//! inject deterministic panics / I/O errors / latency / hangs at the
//! sim, store, and PJRT-load boundaries. See `util::fault` and
//! `docs/robustness.md`.

use damov::coordinator::{default_results_dir, reports, Coordinator};
use damov::util::cancel;
use damov::methodology::classify::{self, Features};
use damov::methodology::locality;
use damov::methodology::step3::{
    profile_all_fallible, profile_function, profile_function_tuned, ReplayParallelism,
    SweepOptions,
};
use damov::runtime::{artifact, Analytics};
use damov::sim::{simulate, CoreModel, SystemSpec};
use damov::util::cli::Args;
use damov::util::json::Json;
use damov::util::pool::{self, default_threads};
use damov::util::telemetry::{self, metrics};
use damov::workloads::{registry, Scale};

fn main() {
    telemetry::init_from_env();
    let args = Args::parse(
        std::env::args().skip(1),
        &["refresh", "inorder", "no-artifacts", "resume"],
    );
    validate_cli(&args);
    match args.command.as_deref() {
        Some("list") => cmd_list(),
        Some("config") => print!("{}", reports::tab1()),
        Some("sim") => cmd_sim(&args),
        Some("characterize") => cmd_characterize(&args),
        Some("step1") => cmd_step1(&args),
        Some("report") => cmd_report(&args),
        Some("validate") => cmd_report_named(&args, &["validation"]),
        Some("bench") => cmd_bench(&args),
        Some("systems") => cmd_systems(&args),
        Some(other) => {
            eprintln!("unknown command {other:?}");
            usage();
            std::process::exit(2);
        }
        None => {
            usage();
        }
    }
    // Export the Chrome trace (DAMOV_TRACE) after the command finishes.
    telemetry::flush();
}

/// Per-command allow-lists for options and flags: a typo'd `--scael` or
/// `--verbose` is a usage error (status 2) with a hint, never silently
/// ignored.
fn validate_cli(args: &Args) {
    let (opts, flags): (&[&str], &[&str]) = match args.command.as_deref() {
        Some("list") | Some("config") => (&[], &[]),
        Some("sim") => (&["code", "cores", "scale", "system"], &["inorder"]),
        Some("characterize") => (&["code", "scale"], &["no-artifacts", "inorder"]),
        Some("step1") => (&["scale", "threads"], &[]),
        Some("report") | Some("validate") => (
            &[
                "threads",
                "scale",
                "results",
                "limit",
                "max-retries",
                "job-timeout",
                "sweep-deadline",
                "systems",
            ],
            &["refresh", "resume", "no-artifacts"],
        ),
        Some("bench") => (
            &["scale", "threads", "limit", "out", "check", "baseline-out"],
            &[],
        ),
        Some("systems") => (&["out"], &[]),
        _ => return, // unknown command / no command: handled in main()
    };
    let cmd = args.command.as_deref().unwrap_or("");
    let mut bad = Vec::new();
    for k in args.options.keys() {
        if !opts.contains(&k.as_str()) {
            bad.push(k.clone());
        }
    }
    for fl in &args.flags {
        if !opts.contains(&fl.as_str()) && !flags.contains(&fl.as_str()) {
            bad.push(fl.clone());
        }
    }
    if !bad.is_empty() {
        for b in &bad {
            eprintln!("unknown option --{b} for `damov {cmd}`");
        }
        let mut supported: Vec<&str> = opts.iter().chain(flags.iter()).copied().collect();
        supported.sort_unstable();
        if supported.is_empty() {
            eprintln!("`damov {cmd}` takes no options");
        } else {
            eprintln!("supported options for `damov {cmd}`: --{}", supported.join(" --"));
        }
        std::process::exit(2);
    }
}

fn usage() {
    eprintln!(
        "usage: damov <list|config|sim|step1|characterize|report|validate|bench|systems> [options]\n\
         common: --threads N --scale X --refresh --results DIR\n\
         bench: damov bench [--scale tiny|full|X] [--limit N] [--out BENCH_sweep.json]\n\
         \x20      [--check rust/tests/golden/bench-baseline.json] [--baseline-out FILE] (docs/performance.md)\n\
         systems: damov systems [list|<preset>] [--out FILE] (dump a spec as JSON; docs/systems.md)\n\
         \x20        report/validate take --systems <preset|spec.json>,... to sweep custom systems\n\
         robustness: --resume (continue an interrupted sweep from its checkpoint)\n\
         \x20           --max-retries N (retries per panicking worker job, default 2)\n\
         \x20           --job-timeout D (soft-cancel any job running longer than D, e.g. 2s)\n\
         \x20           --sweep-deadline D (wall-clock budget for the whole sweep)\n\
         \x20           --limit N (sweep only the first N representatives; 0 = all)\n\
         \x20           DAMOV_FAULT_SPEC=panic:P,io:P,delay:P,hang:P,seed:S (deterministic fault injection)\n\
         telemetry: DAMOV_TRACE=trace.json (Chrome/Perfetto trace)\n\
         \x20          DAMOV_LOG=events.jsonl|- (structured JSONL event log)\n\
         \x20          DAMOV_LOG_LEVEL=error|warn|info|debug (default info)\n\
         see `damov report all --threads 16` to regenerate every figure,\n\
         `damov report health` for sweep coverage after a degraded run,\n\
         `damov report telemetry` for the metrics snapshot (docs/telemetry.md)"
    );
}

fn cmd_list() {
    println!("{:28} {:14} {:6} {}", "code", "input", "class", "representative");
    for f in registry::all_functions() {
        println!(
            "{:28} {:14} {:6} {}",
            f.id.code(),
            f.id.input,
            f.paper_class.unwrap_or(f.family_class),
            f.representative
        );
    }
}

/// Resolve one `--system`/`--systems` entry — a preset name or a path
/// to a spec-JSON file — or exit with a usage error (status 2).
fn resolve_system(arg: &str) -> SystemSpec {
    SystemSpec::resolve(arg).unwrap_or_else(|e| {
        eprintln!("invalid system {arg:?}: {e}");
        eprintln!(
            "presets: host, host+pf, ndp, host-nuca; or a path to a spec JSON \
             (see `damov systems` and docs/systems.md)"
        );
        std::process::exit(2);
    })
}

/// Parse `--systems a,b,c` into an ordered spec list (the first entry
/// is the normalization baseline). `None` when the flag is absent.
fn systems_flag(args: &Args) -> Option<Vec<SystemSpec>> {
    args.opt("systems").map(|list| {
        let specs: Vec<SystemSpec> = list
            .split(',')
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .map(resolve_system)
            .collect();
        if specs.is_empty() {
            eprintln!("--systems expects a comma-separated list of presets or spec-JSON paths");
            std::process::exit(2);
        }
        for (i, a) in specs.iter().enumerate() {
            if specs[..i].iter().any(|b| b.name == a.name) {
                eprintln!("--systems lists {:?} twice (spec names must be unique)", a.name);
                std::process::exit(2);
            }
        }
        specs
    })
}

/// `damov systems [name]`: list the built-in presets, or dump one
/// preset / custom spec as normalized spec JSON (stdout, or --out FILE).
fn cmd_systems(args: &Args) {
    match args.positional.first().map(String::as_str) {
        None | Some("list") => {
            println!("{:10} {:34} {}", "name", "hierarchy", "backend");
            for s in SystemSpec::presets() {
                let caches: Vec<String> = s
                    .caches
                    .iter()
                    .enumerate()
                    .map(|(i, l)| {
                        format!(
                            "L{}:{}KiB{}",
                            i + 1,
                            l.size_bytes >> 10,
                            if l.shared { "(shared)" } else { "" }
                        )
                    })
                    .collect();
                println!("{:10} {:34} {}", s.name, caches.join(" "), s.backend.label());
            }
            println!(
                "\n`damov systems <name>` dumps a preset as spec JSON (--out FILE to save);\n\
                 custom specs run with --system/--systems <file.json> (docs/systems.md)"
            );
        }
        Some(name) => {
            let spec = resolve_system(name);
            let text = spec.to_json().to_string_pretty();
            match args.opt("out") {
                Some(path) => {
                    if let Err(e) = std::fs::write(path, format!("{text}\n")) {
                        eprintln!("could not write {path:?}: {e}");
                        std::process::exit(1);
                    }
                    eprintln!("wrote {path}");
                }
                None => println!("{text}"),
            }
        }
    }
}

fn cmd_sim(args: &Args) {
    let code = args.opt_or("code", "STRTriad");
    let spec = registry::by_code(code).unwrap_or_else(|| {
        eprintln!("unknown function {code:?}; see `damov list`");
        std::process::exit(2);
    });
    let cores = args.opt_usize("cores", 4);
    let scale = scale_flag(args, 1.0);
    let model = if args.flag("inorder") {
        CoreModel::InOrder
    } else {
        CoreModel::OutOfOrder
    };
    let sys = resolve_system(args.opt_or("system", "host"));
    let cfg = sys.build(cores, model);
    let trace = spec.trace(cores, scale);
    let accesses: usize = trace.iter().map(Vec::len).sum();
    let t0 = std::time::Instant::now();
    let r = simulate(&cfg, &trace);
    let wall = t0.elapsed();
    println!(
        "{code} on {} x{cores} ({model:?}): {accesses} accesses in {:.2?} ({:.1} M acc/s)",
        cfg.label,
        wall,
        accesses as f64 / wall.as_secs_f64() / 1e6
    );
    println!(
        "  perf={:.1}  ipc={:.2}  memory_bound={:.2}  mpki={:.2}  lfmr={:.3}  ai={:.2}",
        r.perf(),
        r.ipc,
        r.memory_bound,
        r.mpki,
        r.lfmr,
        r.ai
    );
    println!(
        "  amat={:.1} cyc {:?}  level fracs={:?}",
        r.amat,
        r.amat_parts.map(|x| x.round()),
        r.level_fracs.map(|x| (x * 1000.0).round() / 10.0)
    );
    println!(
        "  bw={:.1} GB/s rho={:.2} row-hit={:.2} energy={:.3e} J (dram {:.0}%)",
        r.bw_bytes_s / 1e9,
        r.dram_rho,
        r.row_hit_rate,
        r.energy.total(),
        r.energy.dram / r.energy.total().max(1e-30) * 100.0
    );
}

/// §3.1 Step-1 scan: rank every suite function by its top-down
/// Memory Bound %, the way the paper filters its 345-application corpus.
fn cmd_step1(args: &Args) {
    let scale = scale_flag(args, 0.25);
    let threads = args.opt_usize("threads", default_threads());
    let specs = registry::all_functions();
    telemetry::info(
        "progress",
        &[("msg", Json::from(format!("step-1 scan over {} functions...", specs.len())))],
    );
    let mut results = damov::methodology::step1::filter_memory_bound(&specs, scale, threads);
    results.sort_by(|a, b| b.memory_bound.partial_cmp(&a.memory_bound).unwrap());
    println!("{:28} {:>12}  {}", "function", "mem-bound %", "selected(>30%)");
    for r in &results {
        println!(
            "{:28} {:>11.1}%  {}",
            r.code,
            r.memory_bound * 100.0,
            if r.selected { "yes" } else { "NO" }
        );
    }
    let n_sel = results.iter().filter(|r| r.selected).count();
    println!("
{}/{} functions pass the 30% Memory-Bound filter", n_sel, results.len());
}

fn cmd_characterize(args: &Args) {
    let code = args.opt_or("code", "STRTriad");
    let spec = registry::by_code(code).unwrap_or_else(|| {
        eprintln!("unknown function {code:?}");
        std::process::exit(2);
    });
    let scale = scale_flag(args, 1.0);
    println!("Step 1: memory-bound identification");
    let s1 = damov::methodology::step1::identify(&spec, scale);
    println!(
        "  memory_bound = {:.1}% -> {}",
        s1.memory_bound * 100.0,
        if s1.selected { "selected" } else { "not memory-bound" }
    );

    println!("Step 2: architecture-independent locality");
    let trace = spec.locality_trace(scale);
    let loc = if !args.flag("no-artifacts") && artifact::artifacts_available() {
        // PJRT is an accelerator, not a dependency: any failure — load,
        // compile, or execute — degrades to the native Rust oracle.
        match Analytics::load(&artifact::default_artifact_dir()) {
            Ok(an) => match an.locality(&trace) {
                Ok(m) => {
                    println!("  (computed via AOT Pallas artifact on PJRT)");
                    m
                }
                Err(e) => {
                    damov::runtime::degraded("pjrt-locality", "native-rust", e);
                    locality::locality(&trace)
                }
            },
            Err(e) => {
                damov::runtime::degraded("pjrt-load", "native-rust", e);
                locality::locality(&trace)
            }
        }
    } else {
        locality::locality(&trace)
    };
    println!("  spatial = {:.3}  temporal = {:.3}", loc.spatial, loc.temporal);

    println!("Step 3: scalability analysis + classification");
    let profile = profile_function(
        &spec,
        SweepOptions {
            scale,
            ..Default::default()
        },
    );
    println!(
        "  AI = {:.2}  MPKI = {:.2}  LFMR = {:.3} (slope {:+.3})",
        profile.ai,
        profile.mpki,
        profile.lfmr_mean(),
        profile.lfmr_slope()
    );
    for &c in damov::sim::CORE_SWEEP.iter() {
        println!(
            "  {:>3} cores: host {:>8.1}  host+pf {:>8.1}  ndp {:>8.1}  (ndp/host {:.2})",
            c,
            profile.norm_perf("host", CoreModel::OutOfOrder, c),
            profile.norm_perf("host+pf", CoreModel::OutOfOrder, c),
            profile.norm_perf("ndp", CoreModel::OutOfOrder, c),
            profile.ndp_speedup(CoreModel::OutOfOrder, c),
        );
    }
    // Classify against paper-calibrated default thresholds when no full
    // representative sweep is available.
    // Default thresholds calibrated on this repo's representative suite
    // (the `damov validate` report derives them from data; the paper's
    // corpus yields 0.48 / 8.5 / 11.0 / 0.56 on its own scale).
    let thr = classify::Thresholds {
        temporal: 0.30,
        ai: 8.5,
        mpki: 45.0,
        lfmr: 0.56,
        slope_dec: -0.25,
        slope_inc: 0.25,
    };
    let mut feats = Features::of(&profile);
    feats.temporal = loc.temporal;
    let class = classify::classify(&feats, &thr);
    println!(
        "  => class {} ({}){}",
        class.label(),
        class.description(),
        spec.paper_class
            .map(|c| format!("  [paper: {c}]"))
            .unwrap_or_default()
    );
}

const ALL_REPORTS: [&str; 27] = [
    "tab1", "fig1", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11",
    "fig12", "fig13", "fig14", "fig15", "fig16", "fig17", "fig18", "fig19", "fig20", "fig22",
    "fig23", "fig24", "tab8", "validation", "health", "telemetry",
];

fn cmd_report(args: &Args) {
    let mut wanted: Vec<String> = args.positional.clone();
    // Validate every requested name *before* any (potentially
    // hours-long) sweep starts, and exit non-zero on a typo.
    let known = |w: &str| {
        ALL_REPORTS.contains(&w) || matches!(w, "all" | "fig21" | "fig25" | "val")
    };
    let bad: Vec<&String> = wanted.iter().filter(|w| !known(w)).collect();
    if !bad.is_empty() {
        for b in &bad {
            eprintln!("unknown report {b:?}");
        }
        eprintln!("known reports: all {}", ALL_REPORTS.join(" "));
        std::process::exit(2);
    }
    if wanted.is_empty() || wanted.iter().any(|w| w == "all") {
        wanted = ALL_REPORTS.iter().map(|s| s.to_string()).collect();
    }
    let names: Vec<&str> = wanted.iter().map(String::as_str).collect();
    cmd_report_named(args, &names);
}

/// Parse `--scale`: a number, or the named presets `tiny` (0.05) and
/// `full` (1.0). Exits with a usage error (status 2) on anything else.
fn scale_flag(args: &Args, default: f64) -> Scale {
    match args.opt("scale") {
        None => Scale(default),
        Some("tiny") => Scale::tiny(),
        Some("full") => Scale::full(),
        Some(v) => match v.parse::<f64>() {
            Ok(x) => Scale(x),
            Err(_) => {
                eprintln!("invalid --scale {v:?} (expected a number, `tiny`, or `full`)");
                std::process::exit(2);
            }
        },
    }
}

/// Parse an optional `--job-timeout`-style duration flag; exits with a
/// usage error (status 2) naming the flag when the value is malformed.
fn duration_flag(args: &Args, name: &str) -> Option<std::time::Duration> {
    args.opt(name).map(|v| {
        cancel::parse_duration(v).unwrap_or_else(|e| {
            eprintln!("invalid --{name} {v:?}: {e}");
            std::process::exit(2);
        })
    })
}

fn cmd_report_named(args: &Args, wanted: &[&str]) {
    let threads = args.opt_usize("threads", default_threads());
    let refresh = args.flag("refresh");
    let results_dir = args
        .opt("results")
        .map(Into::into)
        .unwrap_or_else(default_results_dir);
    let coord = Coordinator::new(&results_dir, threads)
        .with_recovery(args.opt_u64("max-retries", 2) as u32, args.flag("resume"))
        .with_deadlines(
            duration_flag(args, "job-timeout"),
            duration_flag(args, "sweep-deadline"),
        );
    let scale = scale_flag(args, 1.0);
    let limit = match args.opt_usize("limit", 0) {
        0 => None,
        n => Some(n),
    };
    // `--systems a,b,c` sweeps custom specs instead of the paper's four.
    let systems = systems_flag(args);

    let needs_reps = wanted
        .iter()
        .any(|w| !matches!(*w, "tab1" | "fig22" | "telemetry"));
    let needs_holdout = wanted
        .iter()
        .any(|w| matches!(*w, "fig18" | "tab8" | "validation" | "val"));

    let reps = if needs_reps {
        let n = limit.unwrap_or(registry::representatives().len());
        telemetry::info(
            "progress",
            &[("msg", Json::from(format!(
                "profiling {n} representatives ({threads} threads)..."
            )))],
        );
        match &systems {
            Some(sys) => {
                coord.representative_profiles_systems(refresh, scale, limit, sys.clone())
            }
            None => coord.representative_profiles_scaled(refresh, scale, limit),
        }
    } else {
        Vec::new()
    };
    let holdout = if needs_holdout {
        telemetry::info(
            "progress",
            &[("msg", Json::from("profiling 100 held-out variants..."))],
        );
        coord.holdout_profiles(refresh)
    } else {
        Vec::new()
    };
    let all: Vec<_> = reps.iter().chain(holdout.iter()).cloned().collect();

    // Fig 3 prefers the PJRT k-means artifact when available.
    let pjrt_fig3: Option<Vec<usize>> = if wanted.contains(&"fig3")
        && !args.flag("no-artifacts")
        && artifact::artifacts_available()
    {
        Analytics::load(&artifact::default_artifact_dir())
            .ok()
            .and_then(|an| an.kmeans(&reports::fig3_points(&reps), 2, 50, 42).ok())
            .map(|(assign, _)| assign)
    } else {
        None
    };

    for name in wanted {
        let text = match *name {
            "tab1" => reports::tab1(),
            "fig1" => reports::fig1(&reps),
            "fig3" => reports::fig3(&reps, pjrt_fig3.as_deref()),
            "fig4" => reports::fig4(&reps),
            "fig5" => reports::fig5(&reps),
            "fig6" => reports::fig6(&reps),
            "fig7" => reports::fig_energy(&reps, "7", ["HSJNPO", "LIGPrkEmd"], "1a"),
            "fig8" => reports::fig_amat(&reps, "8", ["CHAHsti", "PLYalu"], "1b"),
            "fig9" => reports::fig_energy(&reps, "9", ["CHAHsti", "PLYalu"], "1b"),
            "fig10" => reports::fig_energy(&reps, "10", ["DRKRes", "PRSFlu"], "1c"),
            "fig11" => reports::fig11(&reps),
            "fig12" => reports::fig_energy(&reps, "12", ["PLYGramSch", "SPLFftRev"], "2a"),
            "fig13" => reports::fig_amat(&reps, "13", ["PLYgemver", "SPLLucb"], "2b"),
            "fig14" => reports::fig_energy(&reps, "14", ["PLYgemver", "SPLLucb"], "2b"),
            "fig15" => reports::fig_energy(&reps, "15", ["HPGSpm", "RODNw"], "2c"),
            "fig16" => reports::fig16(&reps),
            "fig17" => reports::fig17(&reps),
            "fig18" => reports::fig18(&all),
            "fig19" => reports::fig19(&reps),
            "fig20" | "fig21" => reports::fig20_21(scale),
            "fig22" => reports::fig22(),
            "fig23" => reports::fig23(scale),
            "fig24" | "fig25" => reports::fig24_25(&reps),
            "tab8" => reports::tab8(&reps, &holdout),
            "validation" | "val" => reports::validation(&reps, &holdout),
            "health" => {
                let sys = systems.clone().unwrap_or_else(SystemSpec::paper_sweep);
                let (expected, _) =
                    Coordinator::representative_sweep_systems(scale, limit, sys.clone());
                reports::sweep_health(
                    &expected,
                    &reps,
                    &coord.representative_retryable_systems(scale, limit, sys),
                )
            }
            "telemetry" => reports::telemetry_report(),
            other => {
                // Unreachable via `damov report` (names are validated up
                // front), but a direct caller still gets a hard error.
                eprintln!("unknown report {other:?}");
                eprintln!("known reports: all {}", ALL_REPORTS.join(" "));
                std::process::exit(2);
            }
        };
        println!("{text}");
        let path = results_dir.join(format!("{name}.txt"));
        if let Err(e) = std::fs::write(&path, &text) {
            telemetry::warn(
                "store",
                &[("detail", Json::from(format!("could not write {path:?}: {e}")))],
            );
        }
    }
}

/// Per-phase CPU time (µs) accumulated in the telemetry registry's span
/// histograms. The registry is always on, so a bench pass is just a
/// before/after delta — no special instrumentation mode.
#[derive(Clone, Copy)]
struct PhaseCpu {
    trace_gen: u64,
    analysis: u64,
    replay: u64,
    timing: u64,
}

impl PhaseCpu {
    fn now() -> PhaseCpu {
        PhaseCpu {
            trace_gen: metrics::histogram("span.trace-gen.us").sum(),
            analysis: metrics::histogram("span.trace-analysis.us").sum(),
            replay: metrics::histogram("span.replay.us").sum(),
            timing: metrics::histogram("span.timing.us").sum(),
        }
    }

    fn since(self, before: PhaseCpu) -> PhaseCpu {
        PhaseCpu {
            trace_gen: self.trace_gen - before.trace_gen,
            analysis: self.analysis - before.analysis,
            replay: self.replay - before.replay,
            timing: self.timing - before.timing,
        }
    }

    fn total(self) -> u64 {
        self.trace_gen + self.analysis + self.replay + self.timing
    }
}

/// One timed sweep pass (serial reference or parallel fast path).
struct BenchPass {
    wall_s: f64,
    accesses: u64,
    cpu: PhaseCpu,
}

impl BenchPass {
    fn run(work: impl FnOnce()) -> BenchPass {
        let cpu0 = PhaseCpu::now();
        let acc0 = metrics::counter("sim.accesses").get();
        let t0 = std::time::Instant::now();
        work();
        BenchPass {
            wall_s: t0.elapsed().as_secs_f64(),
            accesses: metrics::counter("sim.accesses").get() - acc0,
            cpu: PhaseCpu::now().since(cpu0),
        }
    }

    /// Wall time attributed to the replay phase: total wall scaled by
    /// the replay share of phase CPU. Under parallel replay the CPU
    /// share is unchanged but the wall shrinks, so this is the quantity
    /// the ≥2x speedup target and the CI regression gate are defined on
    /// (docs/performance.md).
    fn replay_wall_s(&self) -> f64 {
        let total = self.cpu.total();
        if total == 0 {
            return 0.0;
        }
        self.wall_s * self.cpu.replay as f64 / total as f64
    }

    fn to_json(&self) -> Json {
        let mut phases = Json::obj();
        phases
            .set("trace_gen_us", self.cpu.trace_gen)
            .set("trace_analysis_us", self.cpu.analysis)
            .set("replay_us", self.cpu.replay)
            .set("timing_us", self.cpu.timing);
        let mut j = Json::obj();
        j.set("wall_s", self.wall_s)
            .set("accesses", self.accesses)
            .set("replay_wall_s", self.replay_wall_s())
            .set(
                "replay_macc_per_s",
                self.accesses as f64 / self.replay_wall_s().max(1e-9) / 1e6,
            )
            .set("phase_cpu", phases);
        j
    }
}

/// `damov bench`: time trace-gen / trace-analysis / replay / timing over
/// the representative sweep, serial reference vs the parallel SoA fast
/// path, and emit `BENCH_sweep.json`. With `--check BASELINE`, enforce
/// the committed performance floor (exit 3 on regression); thresholds
/// and attribution are documented in docs/performance.md.
fn cmd_bench(args: &Args) {
    let scale = scale_flag(args, Scale::tiny().0);
    let threads = args.opt_usize("threads", default_threads());
    let mut specs = registry::representatives();
    let limit = args.opt_usize("limit", 0);
    if limit > 0 {
        specs.truncate(limit);
    }
    let opt = SweepOptions {
        scale,
        ..Default::default()
    };
    eprintln!(
        "bench: {} functions at scale {}, {} threads (budget {})",
        specs.len(),
        scale.0,
        threads,
        pool::budget_total()
    );

    // Serial reference: the historical nested loop, one function at a
    // time on this thread, one config point at a time.
    let serial = BenchPass::run(|| {
        for s in &specs {
            std::hint::black_box(profile_function_tuned(s, opt.clone(), ReplayParallelism::Serial));
        }
    });
    // Fast path: the production scheduler — functions fan out over the
    // worker pool, each trace's config points fan out over whatever the
    // global thread budget has left.
    let parallel = BenchPass::run(|| {
        for r in profile_all_fallible(&specs, opt, threads, 0) {
            std::hint::black_box(r.unwrap_or_else(|e| panic!("bench sweep failed: {e}")));
        }
    });

    let total_speedup = serial.wall_s / parallel.wall_s.max(1e-9);
    let replay_speedup = serial.replay_wall_s() / parallel.replay_wall_s().max(1e-9);
    eprintln!(
        "bench: serial {:.3}s (replay {:.3}s) | parallel {:.3}s (replay {:.3}s) | speedup total {:.2}x replay {:.2}x",
        serial.wall_s,
        serial.replay_wall_s(),
        parallel.wall_s,
        parallel.replay_wall_s(),
        total_speedup,
        replay_speedup
    );

    let mut speedup = Json::obj();
    speedup
        .set("total_wall", total_speedup)
        .set("replay_wall", replay_speedup);
    let mut out = Json::obj();
    out.set("schema", 1u64)
        .set("scale", scale.0)
        .set("threads", threads)
        .set("budget_threads", pool::budget_total())
        .set("functions", specs.len())
        .set("serial", serial.to_json())
        .set("parallel", parallel.to_json())
        .set("speedup", speedup);
    let out_path = args.opt_or("out", "BENCH_sweep.json");
    if let Err(e) = std::fs::write(out_path, out.to_string_pretty()) {
        eprintln!("could not write {out_path:?}: {e}");
        std::process::exit(1);
    }
    eprintln!("bench: wrote {out_path}");

    // Record a machine-local baseline that later runs gate against with
    // `--check`: pins the parallel replay wall plus the regression budget.
    if let Some(baseline_path) = args.opt("baseline-out") {
        let mut base = Json::obj();
        base.set("schema", 1u64)
            .set("min_replay_speedup", 2.0)
            .set("replay_wall_s", parallel.replay_wall_s())
            .set("max_regression", 1.10);
        if let Err(e) = std::fs::write(baseline_path, base.to_string_pretty()) {
            eprintln!("could not write {baseline_path:?}: {e}");
            std::process::exit(1);
        }
        eprintln!("bench: wrote baseline {baseline_path}");
    }

    if let Some(baseline_path) = args.opt("check") {
        let base = std::fs::read_to_string(baseline_path)
            .map_err(|e| e.to_string())
            .and_then(|text| Json::parse(&text))
            .unwrap_or_else(|e| {
                eprintln!("could not load baseline {baseline_path:?}: {e}");
                std::process::exit(2);
            });
        let mut failures = Vec::new();
        // The relative-speedup floor only means something with real
        // parallelism available; small CI runners skip it.
        if let Some(min) = base.get("min_replay_speedup").and_then(Json::as_f64) {
            if threads >= 4 && pool::budget_total() >= 4 && replay_speedup < min {
                failures.push(format!("replay speedup {replay_speedup:.2}x < floor {min:.2}x"));
            }
        }
        // Absolute replay wall gate, enforced only once a machine-local
        // baseline has been recorded (the committed value is null).
        if let Some(base_wall) = base.get("replay_wall_s").and_then(Json::as_f64) {
            let max_regression = base
                .get("max_regression")
                .and_then(Json::as_f64)
                .unwrap_or(1.25);
            let limit = base_wall * max_regression;
            if parallel.replay_wall_s() > limit {
                failures.push(format!(
                    "parallel replay wall {:.3}s exceeds baseline {base_wall:.3}s x {max_regression} = {limit:.3}s",
                    parallel.replay_wall_s()
                ));
            }
        }
        if failures.is_empty() {
            eprintln!("bench: baseline check passed ({baseline_path})");
        } else {
            for f in &failures {
                eprintln!("bench: REGRESSION: {f}");
            }
            std::process::exit(3);
        }
    }
}
