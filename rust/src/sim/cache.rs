//! Set-associative cache with LRU replacement, dirty bits and
//! back-invalidation support (for the inclusive shared L3).
//!
//! Replay-speed matters (hundreds of millions of lookups per experiment
//! sweep), so the structure is flat arrays indexed by `set * ways + way`,
//! with an 8-bit LRU stamp per way and tag scans over at most 16 ways.

use super::config::CacheConfig;

const INVALID: u64 = u64::MAX;

/// Result of a cache lookup-and-fill.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LookupResult {
    Hit,
    /// Missed; a victim line (tag, dirty) may have been evicted to make room.
    Miss { evicted: Option<Evicted> },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Evicted {
    pub line_addr: u64,
    pub dirty: bool,
}

pub struct Cache {
    sets: usize,
    ways: usize,
    shift: u32,
    /// Line tags (full line address, i.e. `addr >> shift`), INVALID if empty.
    tags: Vec<u64>,
    /// LRU counters: larger = more recently used.
    lru: Vec<u32>,
    dirty: Vec<bool>,
    tick: u32,
    pub hits: u64,
    pub misses: u64,
    pub writebacks: u64,
}

impl Cache {
    pub fn new(cfg: &CacheConfig) -> Cache {
        let sets = cfg.sets();
        assert!(sets.is_power_of_two(), "sets must be a power of two: {sets}");
        Cache {
            sets,
            ways: cfg.ways,
            shift: cfg.line_bytes.trailing_zeros(),
            tags: vec![INVALID; sets * cfg.ways],
            lru: vec![0; sets * cfg.ways],
            dirty: vec![false; sets * cfg.ways],
            tick: 0,
            hits: 0,
            misses: 0,
            writebacks: 0,
        }
    }

    #[inline]
    pub fn line_of(&self, addr: u64) -> u64 {
        addr >> self.shift
    }

    #[inline]
    fn set_of(&self, line: u64) -> usize {
        (line as usize) & (self.sets - 1)
    }

    /// Access `addr`; on miss, allocate the line (write-allocate), evicting
    /// the LRU way. `write` marks the line dirty.
    ///
    /// Hot path: a single fused pass over the set finds a hit *and*
    /// tracks the victim (first empty way, else max-age) so a miss needs
    /// no second scan; slices hoist the bounds checks out of the loop.
    pub fn access(&mut self, addr: u64, write: bool) -> LookupResult {
        let line = addr >> self.shift;
        let set = (line as usize) & (self.sets - 1);
        let base = set * self.ways;
        self.tick = self.tick.wrapping_add(1);
        let tick = self.tick;
        let tags = &mut self.tags[base..base + self.ways];
        let lru = &mut self.lru[base..base + self.ways];
        let mut victim = 0usize;
        let mut oldest_age = 0u32;
        let mut have_empty = false;
        for (w, (&t, &stamp)) in tags.iter().zip(lru.iter()).enumerate() {
            if t == line {
                lru[w] = tick;
                if write {
                    self.dirty[base + w] = true;
                }
                self.hits += 1;
                return LookupResult::Hit;
            }
            if !have_empty {
                if t == INVALID {
                    victim = w;
                    have_empty = true;
                } else {
                    let age = tick.wrapping_sub(stamp);
                    if age >= oldest_age {
                        oldest_age = age;
                        victim = w;
                    }
                }
            }
        }
        self.misses += 1;
        let evicted = if !have_empty {
            let ev_line = tags[victim];
            let ev_dirty = self.dirty[base + victim];
            if ev_dirty {
                self.writebacks += 1;
            }
            Some(Evicted {
                line_addr: ev_line << self.shift,
                dirty: ev_dirty,
            })
        } else {
            None
        };
        tags[victim] = line;
        lru[victim] = tick;
        self.dirty[base + victim] = write;
        LookupResult::Miss { evicted }
    }

    /// Probe without modifying state.
    pub fn contains(&self, addr: u64) -> bool {
        let line = self.line_of(addr);
        let set = self.set_of(line);
        let base = set * self.ways;
        (0..self.ways).any(|w| self.tags[base + w] == line)
    }

    /// Insert a line without counting a demand miss (prefetch fill).
    /// Returns the evicted line, if any.
    pub fn fill(&mut self, addr: u64) -> Option<Evicted> {
        let line = self.line_of(addr);
        let set = self.set_of(line);
        let base = set * self.ways;
        self.tick = self.tick.wrapping_add(1);
        for w in 0..self.ways {
            if self.tags[base + w] == line {
                return None; // already present
            }
        }
        let mut victim = 0;
        let mut oldest_age = 0u32;
        for w in 0..self.ways {
            if self.tags[base + w] == INVALID {
                victim = w;
                break;
            }
            let age = self.tick.wrapping_sub(self.lru[base + w]);
            if age >= oldest_age {
                oldest_age = age;
                victim = w;
            }
        }
        let evicted = if self.tags[base + victim] != INVALID {
            let ev_dirty = self.dirty[base + victim];
            if ev_dirty {
                self.writebacks += 1;
            }
            Some(Evicted {
                line_addr: self.tags[base + victim] << self.shift,
                dirty: ev_dirty,
            })
        } else {
            None
        };
        self.tags[base + victim] = line;
        // Insert with low recency so useless prefetches die fast-ish but a
        // subsequent demand hit promotes the line.
        self.lru[base + victim] = self.tick;
        self.dirty[base + victim] = false;
        evicted
    }

    /// Remove a line (inclusive-L3 back-invalidation). Returns whether the
    /// line was present and dirty.
    pub fn invalidate(&mut self, addr: u64) -> Option<bool> {
        let line = self.line_of(addr);
        let set = self.set_of(line);
        let base = set * self.ways;
        for w in 0..self.ways {
            if self.tags[base + w] == line {
                let was_dirty = self.dirty[base + w];
                self.tags[base + w] = INVALID;
                self.dirty[base + w] = false;
                return Some(was_dirty);
            }
        }
        None
    }

    pub fn reset_stats(&mut self) {
        self.hits = 0;
        self.misses = 0;
        self.writebacks = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::CacheConfig;

    fn tiny(ways: usize, sets: usize) -> Cache {
        Cache::new(&CacheConfig {
            size_bytes: 64 * ways * sets,
            ways,
            line_bytes: 64,
            latency_cycles: 1,
            epj_hit: 0.0,
            epj_miss: 0.0,
        })
    }

    #[test]
    fn hit_after_fill() {
        let mut c = tiny(2, 4);
        assert!(matches!(c.access(0x40, false), LookupResult::Miss { .. }));
        assert_eq!(c.access(0x40, false), LookupResult::Hit);
        assert_eq!(c.access(0x7f, false), LookupResult::Hit); // same line
        assert_eq!(c.hits, 2);
        assert_eq!(c.misses, 1);
    }

    #[test]
    fn lru_evicts_least_recent() {
        let mut c = tiny(2, 1); // 1 set, 2 ways
        c.access(0x000, false); // A
        c.access(0x040, false); // B
        c.access(0x000, false); // touch A => B is LRU
        let r = c.access(0x080, false); // C evicts B
        match r {
            LookupResult::Miss { evicted: Some(ev) } => assert_eq!(ev.line_addr, 0x040),
            other => panic!("expected eviction, got {other:?}"),
        }
        assert!(c.contains(0x000));
        assert!(!c.contains(0x040));
        assert!(c.contains(0x080));
    }

    #[test]
    fn dirty_eviction_counts_writeback() {
        let mut c = tiny(1, 1);
        c.access(0x000, true); // dirty A
        let r = c.access(0x040, false); // evict A
        match r {
            LookupResult::Miss { evicted: Some(ev) } => {
                assert!(ev.dirty);
                assert_eq!(ev.line_addr, 0x000);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(c.writebacks, 1);
    }

    #[test]
    fn write_hit_marks_dirty() {
        let mut c = tiny(1, 1);
        c.access(0x000, false);
        c.access(0x000, true); // write hit -> dirty
        let r = c.access(0x040, false);
        match r {
            LookupResult::Miss { evicted: Some(ev) } => assert!(ev.dirty),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn invalidate_removes_line() {
        let mut c = tiny(2, 2);
        c.access(0x000, true);
        assert_eq!(c.invalidate(0x000), Some(true));
        assert_eq!(c.invalidate(0x000), None);
        assert!(!c.contains(0x000));
    }

    #[test]
    fn fill_does_not_count_demand_miss() {
        let mut c = tiny(2, 2);
        c.fill(0x000);
        assert_eq!(c.misses, 0);
        assert_eq!(c.access(0x000, false), LookupResult::Hit);
    }

    #[test]
    fn fill_existing_line_is_noop() {
        let mut c = tiny(2, 2);
        c.access(0x000, true);
        assert!(c.fill(0x000).is_none());
        // dirtiness preserved
        let _ = c.access(0x080, false);
        let _ = c.access(0x100, false);
        // (line 0x000 may be evicted above; just assert no crash)
    }

    #[test]
    fn working_set_larger_than_cache_misses() {
        let mut c = tiny(8, 64); // 32 KiB
        // Stream 128 KiB twice: second pass should still miss (capacity).
        for pass in 0..2 {
            for i in 0..2048u64 {
                c.access(i * 64, false);
            }
            if pass == 0 {
                assert_eq!(c.misses, 2048);
            }
        }
        assert_eq!(c.misses, 4096);
        assert_eq!(c.hits, 0);
    }

    #[test]
    fn small_working_set_all_hits_after_warmup() {
        let mut c = tiny(8, 64);
        for i in 0..256u64 {
            c.access(i * 64, false);
        }
        c.reset_stats();
        for _ in 0..4 {
            for i in 0..256u64 {
                c.access(i * 64, false);
            }
        }
        assert_eq!(c.misses, 0);
        assert_eq!(c.hits, 1024);
    }
}
