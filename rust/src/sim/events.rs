//! Structure-of-arrays event batching + config-invariant trace analysis.
//!
//! The methodology replays the *same* generated trace across every
//! (system kind × core model) config point of the sweep grid — exactly
//! the redundant data movement the paper teaches us to eliminate. Two
//! substrates make that replay fast:
//!
//! * [`SoaTrace`] — the access stream transposed into parallel columns
//!   (addresses, packed flags, basic blocks, gaps, op counts). The replay
//!   hot loop walks five dense arrays sequentially instead of striding
//!   over 16-byte [`Access`] records, so each 64-access quantum stays in
//!   a handful of cache lines and the hardware prefetchers see pure
//!   streams.
//! * [`TraceAnalysis`] — everything about a trace that does *not* depend
//!   on the simulated system (footprint, per-thread access partitions,
//!   line-reuse histogram) plus the SoA buffer itself, computed once per
//!   (function, core count) and shared read-only by every config point
//!   replayed from it (serially or on parallel lanes; see
//!   `methodology::step3` and `docs/performance.md`).
//!
//! Conversion is lossless: replaying a [`SoaTrace`] visits the exact
//! access sequence of the source [`Trace`], so simulation results are
//! byte-identical to the array-of-structs engine
//! (`rust/tests/golden_profiles.rs` pins this for the whole registry).

use super::{Access, Trace, LINE};
use crate::util::telemetry::{self, metrics};
use std::collections::HashMap;

/// [`CoreEvents::flags`] bit: the access is a store.
pub const FLAG_WRITE: u8 = 1 << 0;
/// [`CoreEvents::flags`] bit: the load's address depends on the previous
/// load's data (pointer chasing).
pub const FLAG_DEP: u8 = 1 << 1;

/// One core's access stream in structure-of-arrays form. All five
/// columns have identical length; element `i` of each column together
/// reconstructs the `i`-th [`Access`] of the source stream.
#[derive(Debug, Clone, Default)]
pub struct CoreEvents {
    pub addr: Vec<u64>,
    /// Packed booleans: [`FLAG_WRITE`] | [`FLAG_DEP`].
    pub flags: Vec<u8>,
    pub bb: Vec<u8>,
    pub gap: Vec<u16>,
    pub ops: Vec<u16>,
}

impl CoreEvents {
    pub fn from_accesses(accs: &[Access]) -> CoreEvents {
        let n = accs.len();
        let mut ev = CoreEvents {
            addr: Vec::with_capacity(n),
            flags: Vec::with_capacity(n),
            bb: Vec::with_capacity(n),
            gap: Vec::with_capacity(n),
            ops: Vec::with_capacity(n),
        };
        for a in accs {
            ev.addr.push(a.addr);
            ev.flags
                .push((a.write as u8) * FLAG_WRITE | (a.dep as u8) * FLAG_DEP);
            ev.bb.push(a.bb);
            ev.gap.push(a.gap);
            ev.ops.push(a.ops);
        }
        ev
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.addr.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.addr.is_empty()
    }

    /// Reconstruct the `i`-th access. Inlined into the replay hot loop,
    /// where the five column reads compile to sequential loads.
    #[inline]
    pub fn get(&self, i: usize) -> Access {
        Access {
            addr: self.addr[i],
            write: self.flags[i] & FLAG_WRITE != 0,
            dep: self.flags[i] & FLAG_DEP != 0,
            bb: self.bb[i],
            gap: self.gap[i],
            ops: self.ops[i],
        }
    }
}

/// A multi-threaded trace in structure-of-arrays form: one
/// [`CoreEvents`] column set per simulated core.
#[derive(Debug, Clone, Default)]
pub struct SoaTrace {
    pub per_core: Vec<CoreEvents>,
    total: usize,
}

impl SoaTrace {
    pub fn from_trace(trace: &Trace) -> SoaTrace {
        let per_core: Vec<CoreEvents> = trace
            .iter()
            .map(|t| CoreEvents::from_accesses(t))
            .collect();
        let total = per_core.iter().map(CoreEvents::len).sum();
        SoaTrace { per_core, total }
    }

    /// Number of simulated cores (threads) in the trace.
    pub fn cores(&self) -> usize {
        self.per_core.len()
    }

    /// Total accesses across all cores.
    pub fn total_accesses(&self) -> usize {
        self.total
    }

    /// Transpose back to array-of-structs form (tests only; replay never
    /// needs it).
    pub fn to_trace(&self) -> Trace {
        self.per_core
            .iter()
            .map(|ev| (0..ev.len()).map(|i| ev.get(i)).collect())
            .collect()
    }
}

/// Config-invariant precomputation for one (function, core count) trace:
/// the SoA replay buffer plus summary statistics that hold for *every*
/// system configuration replaying it (they depend only on the access
/// stream and the fixed 64 B line size, never on cache geometry, core
/// model, or system kind). Computed once, then shared read-only across
/// all config points of the sweep grid.
///
/// The statistics are observational (telemetry/reporting); simulation
/// results are produced solely by replaying [`TraceAnalysis::events`],
/// which is why sharing the analysis cannot perturb byte-identity.
#[derive(Debug, Clone)]
pub struct TraceAnalysis {
    /// The replay buffer every config point consumes.
    pub events: SoaTrace,
    pub total_accesses: usize,
    /// Accesses per thread (the generator's partition of the work).
    pub per_thread_accesses: Vec<usize>,
    /// Unique 64 B lines touched.
    pub footprint_lines: u64,
    pub footprint_bytes: u64,
    /// Histogram of per-line touch counts, log2-bucketed: bucket `b`
    /// counts lines touched in `[2^b, 2^(b+1))`. A mass at bucket 0 is a
    /// streaming footprint; mass in high buckets is a hot working set.
    pub reuse_hist: [u64; 32],
    /// Mean touches per distinct line (locality summary).
    pub mean_touches_per_line: f64,
}

impl TraceAnalysis {
    pub fn new(trace: &Trace) -> TraceAnalysis {
        Self::from_events(SoaTrace::from_trace(trace))
    }

    pub fn from_events(events: SoaTrace) -> TraceAnalysis {
        let _span = telemetry::span("trace-analysis");
        let per_thread_accesses: Vec<usize> =
            events.per_core.iter().map(CoreEvents::len).collect();
        let total_accesses = events.total_accesses();

        let mut touches: HashMap<u64, u64> = HashMap::new();
        for core in &events.per_core {
            for &addr in &core.addr {
                *touches.entry(addr / LINE as u64).or_insert(0) += 1;
            }
        }
        let footprint_lines = touches.len() as u64;
        let mut reuse_hist = [0u64; 32];
        for &n in touches.values() {
            let bucket = (63 - n.max(1).leading_zeros() as usize).min(31);
            reuse_hist[bucket] += 1;
        }
        let mean_touches_per_line = total_accesses as f64 / footprint_lines.max(1) as f64;

        metrics::counter("sweep.trace_analyses").incr();
        metrics::histogram("sweep.footprint_lines").record(footprint_lines);

        TraceAnalysis {
            events,
            total_accesses,
            per_thread_accesses,
            footprint_lines,
            footprint_bytes: footprint_lines * LINE as u64,
            reuse_hist,
            mean_touches_per_line,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_trace() -> Trace {
        vec![
            vec![
                Access::load(0, 2, 3),
                Access::store(64, 0, 1),
                Access::load_dep(128, 7, 0).in_bb(5),
                Access::load(0, 1, 1),
            ],
            vec![Access::store(1 << 30, 9, 2)],
            vec![],
        ]
    }

    #[test]
    fn soa_roundtrip_is_lossless() {
        let t = sample_trace();
        let soa = SoaTrace::from_trace(&t);
        assert_eq!(soa.cores(), 3);
        assert_eq!(soa.total_accesses(), 5);
        assert_eq!(soa.to_trace(), t);
    }

    #[test]
    fn flags_pack_write_and_dep_independently() {
        let soa = SoaTrace::from_trace(&sample_trace());
        let c0 = &soa.per_core[0];
        assert_eq!(c0.flags[0], 0);
        assert_eq!(c0.flags[1], FLAG_WRITE);
        assert_eq!(c0.flags[2], FLAG_DEP);
        assert_eq!(c0.get(2).bb, 5);
    }

    #[test]
    fn analysis_counts_footprint_and_reuse() {
        // Core 0 touches lines {0, 1, 2, 0}; core 1 touches one far line.
        let ta = TraceAnalysis::new(&sample_trace());
        assert_eq!(ta.total_accesses, 5);
        assert_eq!(ta.per_thread_accesses, vec![4, 1, 0]);
        assert_eq!(ta.footprint_lines, 4);
        assert_eq!(ta.footprint_bytes, 4 * LINE as u64);
        // Three lines touched once (bucket 0), one touched twice (bucket 1).
        assert_eq!(ta.reuse_hist[0], 3);
        assert_eq!(ta.reuse_hist[1], 1);
        assert!((ta.mean_touches_per_line - 1.25).abs() < 1e-12);
    }
}
