//! Lowered system configurations (paper Table 1).
//!
//! [`SystemConfig`] is what the engine replays against: a fully resolved
//! set of core/cache/memory parameters. It is *lowered* from the
//! declarative [`SystemSpec`](crate::sim::spec::SystemSpec) layer — the
//! engine never branches on which named system it is running, only on
//! structural facts (which cache slots exist, the memory backend, the
//! L1 write policy).
//!
//! The paper's systems, available as spec presets, differ **only** in
//! the memory hierarchy so that performance/energy deltas isolate data
//! movement:
//!
//! * **host** — private L1 (32 KiB) + L2 (256 KiB), shared inclusive
//!   L3 (8 MiB, 16 banks), off-chip HMC link.
//! * **host+pf** — same, plus an L2 stream prefetcher (2-degree,
//!   16 streams).
//! * **ndp** — cores in the HMC logic layer: private read-only L1 only,
//!   no prefetcher, direct vault access (no off-chip link).
//! * **host-nuca** — §3.4 variant: L3 scales 2 MiB/core, banks on a
//!   2-D mesh NoC (M/D/1 contention, 3 cycles/hop).

/// Core microarchitecture model (paper §2.4.2 uses both).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CoreModel {
    /// 4-wide OoO, 128-entry ROB, 32-entry LSQ.
    OutOfOrder,
    /// 4-wide in-order.
    InOrder,
}

/// How cores reach main memory — the structural axis that used to be
/// implied by the `SystemKind` enum.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MemoryBackend {
    /// Off-chip access over the HMC SerDes link (host CPUs).
    HmcLink,
    /// Logic-layer cores with direct vault access (NDP): no link
    /// latency/energy, internal bandwidth.
    DirectVault,
    /// Host with the LLC distributed over a 2-D mesh NoC (§3.4 NUCA).
    NucaMesh,
}

impl MemoryBackend {
    pub fn label(&self) -> &'static str {
        match self {
            MemoryBackend::HmcLink => "hmc-link",
            MemoryBackend::DirectVault => "direct-vault",
            MemoryBackend::NucaMesh => "nuca-mesh",
        }
    }

    pub fn parse(s: &str) -> Option<MemoryBackend> {
        match s {
            "hmc-link" => Some(MemoryBackend::HmcLink),
            "direct-vault" => Some(MemoryBackend::DirectVault),
            "nuca-mesh" => Some(MemoryBackend::NucaMesh),
            _ => None,
        }
    }
}

/// Geometry/latency of one cache level.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CacheConfig {
    pub size_bytes: usize,
    pub ways: usize,
    pub line_bytes: usize,
    pub latency_cycles: u64,
    /// pJ per hit / per miss (lookup energy), Table 1.
    pub epj_hit: f64,
    pub epj_miss: f64,
}

impl CacheConfig {
    pub fn sets(&self) -> usize {
        self.size_bytes / self.line_bytes / self.ways
    }
}

/// HMC v2.0-like main memory (Table 1 "Common").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DramConfig {
    pub vaults: usize,
    pub banks_per_vault: usize,
    pub row_bytes: usize,
    pub line_bytes: usize,
    /// Core cycles (@2.4 GHz) for a row-buffer hit at the vault.
    pub row_hit_cycles: u64,
    /// Additional cycles for activate (row closed).
    pub act_cycles: u64,
    /// Additional cycles for precharge+activate (row conflict).
    pub pre_act_cycles: u64,
    /// Extra cycles a *host* access pays to cross the off-chip link
    /// (SerDes + controller + round trip).
    pub host_link_cycles: u64,
    /// Peak off-chip link bandwidth usable by the host (bytes/sec).
    pub host_peak_bw: f64,
    /// Peak aggregate internal bandwidth usable by NDP cores (bytes/sec).
    pub ndp_peak_bw: f64,
    /// Energy per bit: DRAM internal, logic layer, off-chip link (pJ/bit).
    pub epj_bit_internal: f64,
    pub epj_bit_logic: f64,
    pub epj_bit_link: f64,
}

impl Default for DramConfig {
    fn default() -> DramConfig {
        // Latencies in 2.4 GHz core cycles. Vault-local access ≈ 21 ns for a
        // row hit, ≈ 42 ns with an activate; the host additionally pays the
        // off-chip SerDes/controller round trip (≈ 40 ns). Peak bandwidths
        // match the paper's §1 STREAM-Copy calibration (115 vs 431 GB/s).
        DramConfig {
            vaults: 32,
            banks_per_vault: 8,
            row_bytes: 256,
            line_bytes: LINE,
            row_hit_cycles: 50,
            act_cycles: 50,
            pre_act_cycles: 100,
            host_link_cycles: 96,
            host_peak_bw: 115.0e9,
            ndp_peak_bw: 431.0e9,
            epj_bit_internal: 2.0,
            epj_bit_logic: 8.0,
            epj_bit_link: 2.0,
        }
    }
}

/// NUCA / NDP-mesh NoC parameters (§3.4, §5.1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NocConfig {
    pub cycles_per_hop: u64,
    /// Energy per request at a router / per link traversal (pJ).
    pub epj_router: f64,
    pub epj_link: f64,
}

impl Default for NocConfig {
    fn default() -> NocConfig {
        NocConfig {
            cycles_per_hop: 3,
            epj_router: 63.0,
            epj_link: 71.0,
        }
    }
}

/// A complete simulated system, lowered from a
/// [`SystemSpec`](crate::sim::spec::SystemSpec) at a concrete
/// (cores, core-model) point.
#[derive(Debug, Clone)]
pub struct SystemConfig {
    /// Label of the spec this config was lowered from — used in
    /// profiles, the results store and report tables.
    pub label: String,
    pub backend: MemoryBackend,
    /// Stores bypass the L1 straight to memory (NDP logic-layer cores).
    pub l1_read_only: bool,
    pub core: CoreModel,
    pub cores: usize,
    pub freq_hz: f64,
    pub issue_width: u64,
    pub rob: u64,
    pub lsq: u64,
    /// Max outstanding L1 misses per core (MSHRs) — MLP ceiling.
    pub mshrs: u64,
    pub l1: CacheConfig,
    /// Private mid-level cache, when the spec declares one.
    pub l2: Option<CacheConfig>,
    /// Shared inclusive LLC, when the spec declares one.
    pub l3: Option<CacheConfig>,
    pub l3_banks: usize,
    pub prefetch: bool,
    /// Prefetcher: number of stream trackers and prefetch degree.
    pub pf_streams: usize,
    pub pf_degree: usize,
    pub dram: DramConfig,
    pub noc: NocConfig,
}

pub const LINE: usize = 64;

impl SystemConfig {
    /// Baseline host CPU (Table 1, fixed 8 MiB L3).
    pub fn host(cores: usize, core: CoreModel) -> SystemConfig {
        super::spec::SystemSpec::host().build(cores, core)
    }

    /// Host + L2 stream prefetcher.
    pub fn host_prefetch(cores: usize, core: CoreModel) -> SystemConfig {
        super::spec::SystemSpec::host_prefetch().build(cores, core)
    }

    /// NDP cores in the logic layer: read-only L1 only, no prefetcher.
    pub fn ndp(cores: usize, core: CoreModel) -> SystemConfig {
        super::spec::SystemSpec::ndp().build(cores, core)
    }

    /// §3.4 NUCA host: L3 = 2 MiB/core on an (n+1)×(n+1) mesh.
    pub fn host_nuca(cores: usize, core: CoreModel) -> SystemConfig {
        super::spec::SystemSpec::host_nuca().build(cores, core)
    }

    /// LLC distributed over the mesh NoC?
    pub fn is_nuca(&self) -> bool {
        self.backend == MemoryBackend::NucaMesh
    }

    /// Cores sit in the logic layer with direct vault access?
    pub fn is_direct_vault(&self) -> bool {
        self.backend == MemoryBackend::DirectVault
    }

    /// Peak DRAM bandwidth this system can draw (bytes/s).
    pub fn peak_bw(&self) -> f64 {
        if self.is_direct_vault() {
            self.dram.ndp_peak_bw
        } else {
            self.dram.host_peak_bw
        }
    }

    /// Mesh side for the NUCA NoC: (n+1)×(n+1) with n = ceil(sqrt(cores)).
    pub fn mesh_side(&self) -> usize {
        let n = (self.cores as f64).sqrt().ceil() as usize;
        n + 1
    }
}

/// The paper's core-count sweep.
pub const CORE_SWEEP: [usize; 5] = [1, 4, 16, 64, 256];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_geometry() {
        let h = SystemConfig::host(4, CoreModel::OutOfOrder);
        assert_eq!(h.l1.sets(), 64);
        assert_eq!(h.l2.unwrap().sets(), 512);
        assert_eq!(h.l3.unwrap().sets(), 8192);
        assert_eq!(h.l3_banks, 16);
        assert_eq!(h.dram.vaults, 32);
        assert_eq!(h.dram.banks_per_vault, 8);
        assert_eq!(h.label, "host");
        assert_eq!(h.backend, MemoryBackend::HmcLink);
    }

    #[test]
    fn ndp_has_single_level() {
        let n = SystemConfig::ndp(16, CoreModel::InOrder);
        assert!(n.l2.is_none() && n.l3.is_none());
        assert!(!n.prefetch);
        assert!(n.l1_read_only);
        assert!(n.peak_bw() > 3.0 * SystemConfig::host(16, CoreModel::InOrder).peak_bw());
    }

    #[test]
    fn nuca_scales_l3_with_cores() {
        let c = SystemConfig::host_nuca(256, CoreModel::OutOfOrder);
        assert_eq!(c.l3.unwrap().size_bytes, 512 << 20);
        assert_eq!(c.l3_banks, 256);
        assert_eq!(c.mesh_side(), 17);
        assert!(c.is_nuca() && !c.is_direct_vault());
    }

    #[test]
    fn bw_ratio_matches_paper_calibration() {
        let c = SystemConfig::host(1, CoreModel::OutOfOrder);
        let ratio = c.dram.ndp_peak_bw / c.dram.host_peak_bw;
        assert!((ratio - 3.7478).abs() < 0.01, "ratio={ratio}");
    }

    #[test]
    fn backend_labels_roundtrip() {
        for b in [
            MemoryBackend::HmcLink,
            MemoryBackend::DirectVault,
            MemoryBackend::NucaMesh,
        ] {
            assert_eq!(MemoryBackend::parse(b.label()), Some(b));
        }
        assert_eq!(MemoryBackend::parse("bogus"), None);
    }
}
