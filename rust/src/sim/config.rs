//! System configurations (paper Table 1).
//!
//! Three primary systems are simulated, differing **only** in the memory
//! hierarchy so that performance/energy deltas isolate data movement:
//!
//! * **Host CPU** — private L1 (32 KiB) + L2 (256 KiB), shared inclusive
//!   L3 (8 MiB, 16 banks), off-chip HMC link.
//! * **Host CPU + prefetcher** — same, plus an L2 stream prefetcher
//!   (2-degree, 16 streams, 64 entries).
//! * **NDP** — cores in the HMC logic layer: private read-only L1 only,
//!   no prefetcher, direct vault access (no off-chip link).
//!
//! Plus the §3.4 variant: **Host NUCA** — L3 scales 2 MiB/core, banks on a
//! 2-D mesh NoC (M/D/1 contention, 3 cycles/hop).

/// Core microarchitecture model (paper §2.4.2 uses both).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CoreModel {
    /// 4-wide OoO, 128-entry ROB, 32-entry LSQ.
    OutOfOrder,
    /// 4-wide in-order.
    InOrder,
}

/// Which of the paper's system configurations to simulate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SystemKind {
    Host,
    HostPrefetch,
    Ndp,
    /// §3.4: host with NUCA L3 scaling 2 MiB per core over a 2-D mesh.
    HostNuca,
}

impl SystemKind {
    pub fn label(&self) -> &'static str {
        match self {
            SystemKind::Host => "host",
            SystemKind::HostPrefetch => "host+pf",
            SystemKind::Ndp => "ndp",
            SystemKind::HostNuca => "host-nuca",
        }
    }
}

/// Geometry/latency of one cache level.
#[derive(Debug, Clone, Copy)]
pub struct CacheConfig {
    pub size_bytes: usize,
    pub ways: usize,
    pub line_bytes: usize,
    pub latency_cycles: u64,
    /// pJ per hit / per miss (lookup energy), Table 1.
    pub epj_hit: f64,
    pub epj_miss: f64,
}

impl CacheConfig {
    pub fn sets(&self) -> usize {
        self.size_bytes / self.line_bytes / self.ways
    }
}

/// HMC v2.0-like main memory (Table 1 "Common").
#[derive(Debug, Clone, Copy)]
pub struct DramConfig {
    pub vaults: usize,
    pub banks_per_vault: usize,
    pub row_bytes: usize,
    pub line_bytes: usize,
    /// Core cycles (@2.4 GHz) for a row-buffer hit at the vault.
    pub row_hit_cycles: u64,
    /// Additional cycles for activate (row closed).
    pub act_cycles: u64,
    /// Additional cycles for precharge+activate (row conflict).
    pub pre_act_cycles: u64,
    /// Extra cycles a *host* access pays to cross the off-chip link
    /// (SerDes + controller + round trip).
    pub host_link_cycles: u64,
    /// Peak off-chip link bandwidth usable by the host (bytes/sec).
    pub host_peak_bw: f64,
    /// Peak aggregate internal bandwidth usable by NDP cores (bytes/sec).
    pub ndp_peak_bw: f64,
    /// Energy per bit: DRAM internal, logic layer, off-chip link (pJ/bit).
    pub epj_bit_internal: f64,
    pub epj_bit_logic: f64,
    pub epj_bit_link: f64,
}

/// NUCA / NDP-mesh NoC parameters (§3.4, §5.1).
#[derive(Debug, Clone, Copy)]
pub struct NocConfig {
    pub cycles_per_hop: u64,
    /// Energy per request at a router / per link traversal (pJ).
    pub epj_router: f64,
    pub epj_link: f64,
}

/// A complete simulated system.
#[derive(Debug, Clone, Copy)]
pub struct SystemConfig {
    pub kind: SystemKind,
    pub core: CoreModel,
    pub cores: usize,
    pub freq_hz: f64,
    pub issue_width: u64,
    pub rob: u64,
    pub lsq: u64,
    /// Max outstanding L1 misses per core (MSHRs) — MLP ceiling.
    pub mshrs: u64,
    pub l1: CacheConfig,
    /// None for NDP (single cache level).
    pub l2: Option<CacheConfig>,
    /// None for NDP. Shared and inclusive when present.
    pub l3: Option<CacheConfig>,
    pub l3_banks: usize,
    pub prefetch: bool,
    /// Prefetcher: number of stream trackers and prefetch degree.
    pub pf_streams: usize,
    pub pf_degree: usize,
    pub dram: DramConfig,
    pub noc: NocConfig,
    /// NUCA: L3 is 2 MiB/core, accessed over the mesh.
    pub nuca: bool,
}

pub const LINE: usize = 64;

fn l1_cfg() -> CacheConfig {
    CacheConfig {
        size_bytes: 32 << 10,
        ways: 8,
        line_bytes: LINE,
        latency_cycles: 4,
        epj_hit: 15.0,
        epj_miss: 33.0,
    }
}

fn l2_cfg() -> CacheConfig {
    CacheConfig {
        size_bytes: 256 << 10,
        ways: 8,
        line_bytes: LINE,
        latency_cycles: 7,
        epj_hit: 46.0,
        epj_miss: 93.0,
    }
}

fn l3_cfg(size_bytes: usize) -> CacheConfig {
    CacheConfig {
        size_bytes,
        ways: 16,
        line_bytes: LINE,
        latency_cycles: 27,
        epj_hit: 945.0,
        epj_miss: 1904.0,
    }
}

fn dram_cfg() -> DramConfig {
    // Latencies in 2.4 GHz core cycles. Vault-local access ≈ 21 ns for a
    // row hit, ≈ 42 ns with an activate; the host additionally pays the
    // off-chip SerDes/controller round trip (≈ 40 ns). Peak bandwidths
    // match the paper's §1 STREAM-Copy calibration (115 vs 431 GB/s).
    DramConfig {
        vaults: 32,
        banks_per_vault: 8,
        row_bytes: 256,
        line_bytes: LINE,
        row_hit_cycles: 50,
        act_cycles: 50,
        pre_act_cycles: 100,
        host_link_cycles: 96,
        host_peak_bw: 115.0e9,
        ndp_peak_bw: 431.0e9,
        epj_bit_internal: 2.0,
        epj_bit_logic: 8.0,
        epj_bit_link: 2.0,
    }
}

fn noc_cfg() -> NocConfig {
    NocConfig {
        cycles_per_hop: 3,
        epj_router: 63.0,
        epj_link: 71.0,
    }
}

impl SystemConfig {
    /// Baseline host CPU (Table 1, fixed 8 MiB L3).
    pub fn host(cores: usize, core: CoreModel) -> SystemConfig {
        SystemConfig {
            kind: SystemKind::Host,
            core,
            cores,
            freq_hz: 2.4e9,
            issue_width: 4,
            rob: 128,
            lsq: 32,
            mshrs: 10,
            l1: l1_cfg(),
            l2: Some(l2_cfg()),
            l3: Some(l3_cfg(8 << 20)),
            l3_banks: 16,
            prefetch: false,
            pf_streams: 16,
            pf_degree: 2,
            dram: dram_cfg(),
            noc: noc_cfg(),
            nuca: false,
        }
    }

    /// Host + L2 stream prefetcher.
    pub fn host_prefetch(cores: usize, core: CoreModel) -> SystemConfig {
        let mut c = Self::host(cores, core);
        c.kind = SystemKind::HostPrefetch;
        c.prefetch = true;
        c
    }

    /// NDP cores in the logic layer: read-only L1 only, no prefetcher.
    pub fn ndp(cores: usize, core: CoreModel) -> SystemConfig {
        let mut c = Self::host(cores, core);
        c.kind = SystemKind::Ndp;
        c.l2 = None;
        c.l3 = None;
        c
    }

    /// §3.4 NUCA host: L3 = 2 MiB/core on an (n+1)×(n+1) mesh.
    pub fn host_nuca(cores: usize, core: CoreModel) -> SystemConfig {
        let mut c = Self::host(cores, core);
        c.kind = SystemKind::HostNuca;
        c.l3 = Some(l3_cfg((2 << 20) * cores));
        c.l3_banks = cores.max(1);
        c.nuca = true;
        c
    }

    pub fn by_kind(kind: SystemKind, cores: usize, core: CoreModel) -> SystemConfig {
        match kind {
            SystemKind::Host => Self::host(cores, core),
            SystemKind::HostPrefetch => Self::host_prefetch(cores, core),
            SystemKind::Ndp => Self::ndp(cores, core),
            SystemKind::HostNuca => Self::host_nuca(cores, core),
        }
    }

    /// Peak DRAM bandwidth this system can draw (bytes/s).
    pub fn peak_bw(&self) -> f64 {
        match self.kind {
            SystemKind::Ndp => self.dram.ndp_peak_bw,
            _ => self.dram.host_peak_bw,
        }
    }

    /// Mesh side for the NUCA NoC: (n+1)×(n+1) with n = ceil(sqrt(cores)).
    pub fn mesh_side(&self) -> usize {
        let n = (self.cores as f64).sqrt().ceil() as usize;
        n + 1
    }
}

/// The paper's core-count sweep.
pub const CORE_SWEEP: [usize; 5] = [1, 4, 16, 64, 256];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_geometry() {
        let h = SystemConfig::host(4, CoreModel::OutOfOrder);
        assert_eq!(h.l1.sets(), 64);
        assert_eq!(h.l2.unwrap().sets(), 512);
        assert_eq!(h.l3.unwrap().sets(), 8192);
        assert_eq!(h.l3_banks, 16);
        assert_eq!(h.dram.vaults, 32);
        assert_eq!(h.dram.banks_per_vault, 8);
    }

    #[test]
    fn ndp_has_single_level() {
        let n = SystemConfig::ndp(16, CoreModel::InOrder);
        assert!(n.l2.is_none() && n.l3.is_none());
        assert!(!n.prefetch);
        assert!(n.peak_bw() > 3.0 * SystemConfig::host(16, CoreModel::InOrder).peak_bw());
    }

    #[test]
    fn nuca_scales_l3_with_cores() {
        let c = SystemConfig::host_nuca(256, CoreModel::OutOfOrder);
        assert_eq!(c.l3.unwrap().size_bytes, 512 << 20);
        assert_eq!(c.l3_banks, 256);
        assert_eq!(c.mesh_side(), 17);
    }

    #[test]
    fn bw_ratio_matches_paper_calibration() {
        let c = SystemConfig::host(1, CoreModel::OutOfOrder);
        let ratio = c.dram.ndp_peak_bw / c.dram.host_peak_bw;
        assert!((ratio - 3.7478).abs() < 0.01, "ratio={ratio}");
    }
}
