//! Network-on-chip models.
//!
//! Two uses in the paper:
//! * §3.4 NUCA host: cores + distributed L3 banks + memory controllers on
//!   an (n+1)×(n+1) 2-D mesh; L3 bank of an address is selected by line
//!   interleaving; each L3 access pays XY-routing hop latency plus M/D/1
//!   link contention (ZSim++'s model), 3 cycles/hop.
//! * §5.1 NDP mesh: 32 vaults' NDP cores on a 6×6 mesh; each remote-vault
//!   memory access pays hop latency; the hop distribution (Fig 21) and
//!   the slowdown vs an ideal zero-latency NoC (Fig 20) are reported.

/// 2-D mesh geometry with XY routing.
#[derive(Debug, Clone, Copy)]
pub struct Mesh {
    pub side_x: usize,
    pub side_y: usize,
}

impl Mesh {
    pub fn new(side_x: usize, side_y: usize) -> Mesh {
        Mesh { side_x, side_y }
    }

    /// Square mesh that fits `n` endpoints.
    pub fn square_for(n: usize) -> Mesh {
        let side = (n as f64).sqrt().ceil() as usize;
        Mesh::new(side.max(1), side.max(1))
    }

    pub fn coords(&self, node: usize) -> (usize, usize) {
        (node % self.side_x, node / self.side_x)
    }

    /// Manhattan hop count between two node ids (XY routing).
    pub fn hops(&self, a: usize, b: usize) -> u64 {
        let (ax, ay) = self.coords(a);
        let (bx, by) = self.coords(b);
        (ax.abs_diff(bx) + ay.abs_diff(by)) as u64
    }

    pub fn nodes(&self) -> usize {
        self.side_x * self.side_y
    }

    /// Mean hops under uniform-random traffic (analytic for a mesh).
    pub fn mean_uniform_hops(&self) -> f64 {
        // E|x1-x2| for uniform over 0..k-1 is (k^2-1)/(3k).
        let ex = |k: usize| {
            let k = k as f64;
            (k * k - 1.0) / (3.0 * k)
        };
        ex(self.side_x) + ex(self.side_y)
    }
}

/// Aggregate NoC contention model: mean per-request latency given a mesh,
/// a mean hop count, per-hop cycles and the offered load. Per ZSim++ we
/// treat each link as an M/D/1 server; utilization is approximated from
/// aggregate traffic spread over the bisection links.
#[derive(Debug, Clone, Copy)]
pub struct NocLoad {
    /// Requests per core-cycle injected into the mesh (aggregate).
    pub inj_rate: f64,
    /// Mean hops per request.
    pub mean_hops: f64,
    /// Service cycles per flit at a link.
    pub service: f64,
}

impl NocLoad {
    /// Mean queuing delay per request in cycles. Total link demand is
    /// `inj_rate * mean_hops` link-traversals/cycle spread over `links`
    /// links; each traversal waits an M/D/1 time at its link.
    pub fn queue_cycles(&self, links: f64) -> f64 {
        if links <= 0.0 {
            return 0.0;
        }
        let rho = (self.inj_rate * self.mean_hops * self.service / links).clamp(0.0, 0.98);
        super::dram::md1_wait(self.service, rho) * self.mean_hops
    }
}

/// Histogram of hop counts (Fig 21): `counts[h]` = requests that traveled
/// `h` hops.
#[derive(Debug, Clone, Default)]
pub struct HopHistogram {
    pub counts: Vec<u64>,
}

impl HopHistogram {
    pub fn record(&mut self, hops: u64) {
        let h = hops as usize;
        if self.counts.len() <= h {
            self.counts.resize(h + 1, 0);
        }
        self.counts[h] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    pub fn fraction(&self, h: usize) -> f64 {
        let t = self.total();
        if t == 0 {
            0.0
        } else {
            *self.counts.get(h).unwrap_or(&0) as f64 / t as f64
        }
    }

    pub fn mean(&self) -> f64 {
        let t = self.total();
        if t == 0 {
            return 0.0;
        }
        self.counts
            .iter()
            .enumerate()
            .map(|(h, c)| h as f64 * *c as f64)
            .sum::<f64>()
            / t as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hops_xy() {
        let m = Mesh::new(6, 6);
        assert_eq!(m.hops(0, 0), 0);
        assert_eq!(m.hops(0, 5), 5);
        assert_eq!(m.hops(0, 35), 10); // corner to corner
        assert_eq!(m.hops(7, 14), 2); // (1,1) -> (2,2)
    }

    #[test]
    fn square_fit() {
        assert_eq!(Mesh::square_for(32).nodes(), 36);
        assert_eq!(Mesh::square_for(1).nodes(), 1);
    }

    #[test]
    fn mean_uniform_hops_reasonable() {
        let m = Mesh::new(6, 6);
        let analytic = m.mean_uniform_hops();
        // Empirical check.
        let mut total = 0u64;
        let mut n = 0u64;
        for a in 0..36 {
            for b in 0..36 {
                total += m.hops(a, b);
                n += 1;
            }
        }
        let emp = total as f64 / n as f64;
        assert!((analytic - emp).abs() < 0.05, "analytic={analytic} emp={emp}");
    }

    #[test]
    fn queue_grows_with_load() {
        let light = NocLoad {
            inj_rate: 0.01,
            mean_hops: 4.0,
            service: 3.0,
        };
        let heavy = NocLoad {
            inj_rate: 0.5,
            mean_hops: 4.0,
            service: 3.0,
        };
        let links = 60.0;
        assert!(heavy.queue_cycles(links) > 10.0 * light.queue_cycles(links));
    }

    #[test]
    fn hop_histogram() {
        let mut h = HopHistogram::default();
        h.record(0);
        h.record(3);
        h.record(3);
        h.record(4);
        assert_eq!(h.total(), 4);
        assert!((h.fraction(3) - 0.5).abs() < 1e-12);
        assert!((h.mean() - 2.5).abs() < 1e-12);
    }
}
