//! Energy accounting (Table 1 pJ/event numbers + §3.4 NoC energy).
//!
//! The engine counts events during cache/DRAM replay; this module turns
//! the counts into the paper's breakdowns: L1 / L2 / L3 / DRAM / off-chip
//! link / NoC, in joules. (Figs 7, 9, 10, 12, 14, 15, 17.)

use super::config::SystemConfig;

/// Raw event counts gathered during replay.
#[derive(Debug, Default, Clone)]
pub struct EnergyEvents {
    pub l1_hits: u64,
    pub l1_misses: u64,
    pub l2_hits: u64,
    pub l2_misses: u64,
    pub l3_hits: u64,
    pub l3_misses: u64,
    /// Bytes that crossed the DRAM core arrays.
    pub dram_bytes: u64,
    /// Bytes that crossed the vault logic layer.
    pub logic_bytes: u64,
    /// Bytes that crossed the off-chip link (host only).
    pub link_bytes: u64,
    /// NoC router traversals / link traversals (NUCA or NDP mesh).
    pub noc_router: u64,
    pub noc_links: u64,
}

/// Energy breakdown in joules.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct EnergyBreakdown {
    pub l1: f64,
    pub l2: f64,
    pub l3: f64,
    pub dram: f64,
    pub link: f64,
    pub noc: f64,
}

impl EnergyBreakdown {
    pub fn total(&self) -> f64 {
        self.l1 + self.l2 + self.l3 + self.dram + self.link + self.noc
    }
}

pub fn energy(cfg: &SystemConfig, ev: &EnergyEvents) -> EnergyBreakdown {
    let pj = 1e-12;
    let l1 = (ev.l1_hits as f64 * cfg.l1.epj_hit + ev.l1_misses as f64 * cfg.l1.epj_miss) * pj;
    let l2 = cfg
        .l2
        .map(|c| (ev.l2_hits as f64 * c.epj_hit + ev.l2_misses as f64 * c.epj_miss) * pj)
        .unwrap_or(0.0);
    let l3 = cfg
        .l3
        .map(|c| (ev.l3_hits as f64 * c.epj_hit + ev.l3_misses as f64 * c.epj_miss) * pj)
        .unwrap_or(0.0);
    let dram = (ev.dram_bytes as f64 * 8.0 * cfg.dram.epj_bit_internal
        + ev.logic_bytes as f64 * 8.0 * cfg.dram.epj_bit_logic)
        * pj;
    let link = ev.link_bytes as f64 * 8.0 * cfg.dram.epj_bit_link * pj;
    let noc = (ev.noc_router as f64 * cfg.noc.epj_router + ev.noc_links as f64 * cfg.noc.epj_link)
        * pj;
    EnergyBreakdown {
        l1,
        l2,
        l3,
        dram,
        link,
        noc,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::{CoreModel, SystemConfig};

    #[test]
    fn ndp_pays_no_l2_l3_link() {
        let cfg = SystemConfig::ndp(4, CoreModel::OutOfOrder);
        let ev = EnergyEvents {
            l1_hits: 1000,
            l1_misses: 100,
            l2_hits: 999, // ignored: no L2
            l3_hits: 999,
            dram_bytes: 6400,
            logic_bytes: 6400,
            link_bytes: 0,
            ..Default::default()
        };
        let e = energy(&cfg, &ev);
        assert_eq!(e.l2, 0.0);
        assert_eq!(e.l3, 0.0);
        assert_eq!(e.link, 0.0);
        assert!(e.l1 > 0.0 && e.dram > 0.0);
    }

    #[test]
    fn host_l3_energy_dominates_cache_energy() {
        // Table 1: L3 hit costs 945 pJ vs 15 pJ L1 — a few L3 accesses
        // outweigh many L1 accesses.
        let cfg = SystemConfig::host(4, CoreModel::OutOfOrder);
        let ev = EnergyEvents {
            l1_hits: 1000,
            l3_hits: 100,
            ..Default::default()
        };
        let e = energy(&cfg, &ev);
        assert!(e.l3 > e.l1);
    }

    #[test]
    fn dram_line_energy_scales_with_bits() {
        let cfg = SystemConfig::host(1, CoreModel::OutOfOrder);
        let ev = EnergyEvents {
            dram_bytes: 64,
            logic_bytes: 64,
            link_bytes: 64,
            ..Default::default()
        };
        let e = energy(&cfg, &ev);
        // 512 bits * (2+8) pJ/bit = 5120 pJ dram, 512*2=1024 pJ link.
        assert!((e.dram - 5120e-12).abs() < 1e-15);
        assert!((e.link - 1024e-12).abs() < 1e-15);
        assert!((e.total() - (e.dram + e.link)).abs() < 1e-18);
    }
}
