//! Data-driven system descriptions: the composable replacement for the
//! old closed `SystemKind` enum.
//!
//! A [`SystemSpec`] declares a complete memory-system design — core
//! parameters, an ordered cache hierarchy, an optional stream
//! prefetcher, and the memory backend — and lowers to the simulator's
//! [`SystemConfig`] for any (cores, core-model) point via
//! [`SystemSpec::build`]. The four paper systems (Table 1) are built-in
//! presets that lower to byte-identical configurations; arbitrary
//! designs load from strictly-validated JSON ([`SystemSpec::load`])
//! without touching Rust, or are composed inline with
//! [`SystemSpec::builder`].
//!
//! Hierarchy shape: the simulator replays against at most three cache
//! slots — a private L1, an optional private L2, and an optional shared
//! LLC. A spec's `caches` list is therefore 1–3 levels: the first must
//! be private, at most one further private level (the L2 slot), and at
//! most one shared level which must come last (the LLC slot). A 2-level
//! `[private, shared]` spec maps the shared level to the LLC slot with
//! no L2 in between.

use super::config::{
    CacheConfig, CoreModel, DramConfig, MemoryBackend, NocConfig, SystemConfig, LINE,
};
use crate::util::json::Json;
use std::fmt;
use std::path::Path;

/// Structured validation/loading error for a [`SystemSpec`].
#[derive(Debug, Clone, PartialEq)]
pub enum SpecError {
    /// File could not be read.
    Io(String),
    /// Not valid JSON.
    Parse(String),
    /// JSON contains a field the schema does not define (strict mode:
    /// typos must not silently become defaults).
    UnknownField(String),
    /// A required field is absent.
    MissingField(String),
    /// A field is present but its value is out of range or mistyped.
    BadValue(String),
    /// The cache list is empty — the simulator needs at least an L1.
    EmptyHierarchy,
    /// The cache list has an unsupported shape.
    Hierarchy(String),
    /// Degenerate cache geometry (e.g. sets divide to 0, or a
    /// non-power-of-two set count) that would panic deep in `Cache::new`.
    Geometry(String),
    /// Bad spec name (empty, or characters the CLI/store cannot carry).
    BadName(String),
}

impl fmt::Display for SpecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SpecError::Io(m) => write!(f, "cannot read spec: {m}"),
            SpecError::Parse(m) => write!(f, "spec is not valid JSON: {m}"),
            SpecError::UnknownField(m) => write!(f, "unknown field {m:?} in system spec"),
            SpecError::MissingField(m) => write!(f, "system spec is missing field {m:?}"),
            SpecError::BadValue(m) => write!(f, "bad value in system spec: {m}"),
            SpecError::EmptyHierarchy => {
                write!(f, "system spec has an empty cache hierarchy (need at least an L1)")
            }
            SpecError::Hierarchy(m) => write!(f, "unsupported cache hierarchy: {m}"),
            SpecError::Geometry(m) => write!(f, "degenerate cache geometry: {m}"),
            SpecError::BadName(m) => write!(f, "bad system name: {m}"),
        }
    }
}

/// Core microarchitecture parameters (identical across the paper's
/// systems, so they default to Table 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CoreParams {
    pub freq_hz: f64,
    pub issue_width: u64,
    pub rob: u64,
    pub lsq: u64,
    /// Max outstanding L1 misses per core (MSHRs) — MLP ceiling.
    pub mshrs: u64,
}

impl Default for CoreParams {
    fn default() -> CoreParams {
        CoreParams {
            freq_hz: 2.4e9,
            issue_width: 4,
            rob: 128,
            lsq: 32,
            mshrs: 10,
        }
    }
}

/// One declared cache level.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CacheLevelSpec {
    pub size_bytes: usize,
    pub ways: usize,
    pub line_bytes: usize,
    pub latency_cycles: u64,
    /// pJ per hit / per miss (lookup energy).
    pub epj_hit: f64,
    pub epj_miss: f64,
    /// Shared across cores (the LLC slot). At most one, and last.
    pub shared: bool,
    /// Bank count of a shared level (ignored for private levels, and
    /// overridden to `cores` when `scale_with_cores` is set).
    pub banks: usize,
    /// NUCA-style LLC: `size_bytes` is *per core* and the bank count
    /// equals the core count. Only valid on the shared level.
    pub scale_with_cores: bool,
}

impl CacheLevelSpec {
    fn to_cache_cfg(self, size_bytes: usize) -> CacheConfig {
        CacheConfig {
            size_bytes,
            ways: self.ways,
            line_bytes: self.line_bytes,
            latency_cycles: self.latency_cycles,
            epj_hit: self.epj_hit,
            epj_miss: self.epj_miss,
        }
    }
}

/// Stream-prefetcher parameters (sits at the private L2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrefetcherSpec {
    pub streams: usize,
    pub degree: usize,
}

/// A complete, declarative system description.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemSpec {
    /// Label used everywhere the system is named: profiles, the results
    /// store, report tables, and the CLI.
    pub name: String,
    pub core: CoreParams,
    /// Ordered cache levels, innermost first. See the module docs for
    /// the supported shapes.
    pub caches: Vec<CacheLevelSpec>,
    /// Stores bypass the L1 straight to memory (NDP logic-layer cores
    /// keep a read-only L1 so no coherence traffic crosses vaults).
    pub l1_read_only: bool,
    pub prefetcher: Option<PrefetcherSpec>,
    pub backend: MemoryBackend,
    pub dram: DramConfig,
    pub noc: NocConfig,
}

fn l1_level() -> CacheLevelSpec {
    CacheLevelSpec {
        size_bytes: 32 << 10,
        ways: 8,
        line_bytes: LINE,
        latency_cycles: 4,
        epj_hit: 15.0,
        epj_miss: 33.0,
        shared: false,
        banks: 16,
        scale_with_cores: false,
    }
}

fn l2_level() -> CacheLevelSpec {
    CacheLevelSpec {
        size_bytes: 256 << 10,
        ways: 8,
        line_bytes: LINE,
        latency_cycles: 7,
        epj_hit: 46.0,
        epj_miss: 93.0,
        shared: false,
        banks: 16,
        scale_with_cores: false,
    }
}

fn l3_level(size_bytes: usize, scale_with_cores: bool) -> CacheLevelSpec {
    CacheLevelSpec {
        size_bytes,
        ways: 16,
        line_bytes: LINE,
        latency_cycles: 27,
        epj_hit: 945.0,
        epj_miss: 1904.0,
        shared: true,
        banks: 16,
        scale_with_cores,
    }
}

impl SystemSpec {
    /// Baseline host CPU (Table 1, fixed 8 MiB L3, off-chip HMC link).
    pub fn host() -> SystemSpec {
        SystemSpec {
            name: "host".to_string(),
            core: CoreParams::default(),
            caches: vec![l1_level(), l2_level(), l3_level(8 << 20, false)],
            l1_read_only: false,
            prefetcher: None,
            backend: MemoryBackend::HmcLink,
            dram: DramConfig::default(),
            noc: NocConfig::default(),
        }
    }

    /// Host + L2 stream prefetcher (2-degree, 16 streams).
    pub fn host_prefetch() -> SystemSpec {
        let mut s = SystemSpec::host();
        s.name = "host+pf".to_string();
        s.prefetcher = Some(PrefetcherSpec {
            streams: 16,
            degree: 2,
        });
        s
    }

    /// NDP cores in the HMC logic layer: read-only L1 only, direct
    /// vault access (no off-chip link).
    pub fn ndp() -> SystemSpec {
        let mut s = SystemSpec::host();
        s.name = "ndp".to_string();
        s.caches = vec![l1_level()];
        s.l1_read_only = true;
        s.backend = MemoryBackend::DirectVault;
        s
    }

    /// §3.4 NUCA host: L3 scales 2 MiB/core, banks on a 2-D mesh NoC.
    pub fn host_nuca() -> SystemSpec {
        let mut s = SystemSpec::host();
        s.name = "host-nuca".to_string();
        s.caches = vec![l1_level(), l2_level(), l3_level(2 << 20, true)];
        s.backend = MemoryBackend::NucaMesh;
        s
    }

    /// All four built-in presets in paper order.
    pub fn presets() -> Vec<SystemSpec> {
        vec![
            SystemSpec::host(),
            SystemSpec::host_prefetch(),
            SystemSpec::ndp(),
            SystemSpec::host_nuca(),
        ]
    }

    /// Look up a preset by name (accepting the CLI's historical
    /// aliases `pf` and `nuca`).
    pub fn preset(name: &str) -> Option<SystemSpec> {
        match name {
            "host" => Some(SystemSpec::host()),
            "host+pf" | "pf" => Some(SystemSpec::host_prefetch()),
            "ndp" => Some(SystemSpec::ndp()),
            "host-nuca" | "nuca" => Some(SystemSpec::host_nuca()),
            _ => None,
        }
    }

    /// The default sweep grid: the paper's three primary systems.
    pub fn default_sweep() -> Vec<SystemSpec> {
        vec![
            SystemSpec::host(),
            SystemSpec::host_prefetch(),
            SystemSpec::ndp(),
        ]
    }

    /// The full report grid: the three primary systems plus the §3.4
    /// NUCA variant.
    pub fn paper_sweep() -> Vec<SystemSpec> {
        SystemSpec::presets()
    }

    /// Resolve a CLI `--systems` element: a preset name or a path to a
    /// JSON spec file.
    pub fn resolve(arg: &str) -> Result<SystemSpec, SpecError> {
        if let Some(p) = SystemSpec::preset(arg) {
            return Ok(p);
        }
        if arg.ends_with(".json") || arg.contains('/') || arg.contains('\\') {
            return SystemSpec::load(Path::new(arg));
        }
        Err(SpecError::BadName(format!(
            "unknown system {arg:?} (presets: host, host+pf, ndp, host-nuca; \
             or give a path to a .json spec file)"
        )))
    }

    /// Lower to a simulator configuration for one (cores, model) point.
    pub fn build(&self, cores: usize, core: CoreModel) -> SystemConfig {
        let l1 = self.caches[0].to_cache_cfg(self.caches[0].size_bytes);
        let mut l2 = None;
        let mut l3 = None;
        let mut l3_banks = 16;
        for level in &self.caches[1..] {
            if level.shared {
                let size = if level.scale_with_cores {
                    level.size_bytes * cores
                } else {
                    level.size_bytes
                };
                l3 = Some(level.to_cache_cfg(size));
                l3_banks = if level.scale_with_cores {
                    cores.max(1)
                } else {
                    level.banks
                };
            } else {
                l2 = Some(level.to_cache_cfg(level.size_bytes));
            }
        }
        SystemConfig {
            label: self.name.clone(),
            backend: self.backend,
            l1_read_only: self.l1_read_only,
            core,
            cores,
            freq_hz: self.core.freq_hz,
            issue_width: self.core.issue_width,
            rob: self.core.rob,
            lsq: self.core.lsq,
            mshrs: self.core.mshrs,
            l1,
            l2,
            l3,
            l3_banks,
            prefetch: self.prefetcher.is_some(),
            pf_streams: self.prefetcher.map_or(16, |p| p.streams),
            pf_degree: self.prefetcher.map_or(2, |p| p.degree),
            dram: self.dram,
            noc: self.noc,
        }
    }

    /// Stable identity of this spec for cache/checkpoint fingerprints:
    /// a hash of the canonical serialization, so a respelled-but-equal
    /// spec (defaults written out, different key order in the source
    /// JSON) fingerprints identically while any semantic difference
    /// changes it.
    pub fn fingerprint(&self) -> String {
        format!(
            "{:016x}",
            crate::util::fault::key_of(&self.to_json().to_string_compact())
        )
    }

    /// Check every structural rule. `Ok(())` means [`build`] lowers to
    /// a configuration the engine can run for any core count without
    /// panicking.
    ///
    /// [`build`]: SystemSpec::build
    pub fn validate(&self) -> Result<(), SpecError> {
        if self.name.is_empty() {
            return Err(SpecError::BadName("name must be non-empty".to_string()));
        }
        if !self
            .name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '+' | '-' | '_' | '.'))
        {
            return Err(SpecError::BadName(format!(
                "{:?}: only alphanumerics and + - _ . are allowed",
                self.name
            )));
        }
        if self.caches.is_empty() {
            return Err(SpecError::EmptyHierarchy);
        }
        if self.caches.len() > 3 {
            return Err(SpecError::Hierarchy(format!(
                "{} levels declared; the simulator supports at most 3 (L1, L2, LLC)",
                self.caches.len()
            )));
        }
        if self.caches[0].shared {
            return Err(SpecError::Hierarchy(
                "the first (innermost) level must be private".to_string(),
            ));
        }
        let shared = self.caches.iter().filter(|l| l.shared).count();
        if shared > 1 {
            return Err(SpecError::Hierarchy(
                "at most one shared (LLC) level is supported".to_string(),
            ));
        }
        if shared == 1 && !self.caches.last().unwrap().shared {
            return Err(SpecError::Hierarchy(
                "the shared (LLC) level must be the last level".to_string(),
            ));
        }
        let mid_private = self.caches[1..].iter().filter(|l| !l.shared).count();
        if mid_private > 1 {
            return Err(SpecError::Hierarchy(
                "at most one private mid-level (L2) is supported".to_string(),
            ));
        }
        for (i, level) in self.caches.iter().enumerate() {
            if level.scale_with_cores && !level.shared {
                return Err(SpecError::BadValue(format!(
                    "caches[{i}]: scale_with_cores is only valid on the shared level"
                )));
            }
            if level.shared && !level.scale_with_cores && level.banks == 0 {
                return Err(SpecError::BadValue(format!(
                    "caches[{i}]: a shared level needs banks >= 1"
                )));
            }
            validate_geometry(i, level)?;
        }
        if let Some(p) = &self.prefetcher {
            let has_private_l2 = self.caches.len() >= 2 && !self.caches[1].shared;
            if !has_private_l2 {
                return Err(SpecError::Hierarchy(
                    "a prefetcher requires a private L2 to sit at".to_string(),
                ));
            }
            if p.streams == 0 || p.degree == 0 {
                return Err(SpecError::BadValue(
                    "prefetcher streams and degree must be >= 1".to_string(),
                ));
            }
        }
        if self.backend == MemoryBackend::NucaMesh && shared == 0 {
            return Err(SpecError::Hierarchy(
                "the nuca-mesh backend requires a shared (LLC) level".to_string(),
            ));
        }
        if !(self.core.freq_hz.is_finite() && self.core.freq_hz > 0.0) {
            return Err(SpecError::BadValue("core.freq_hz must be > 0".to_string()));
        }
        for (what, v) in [
            ("core.issue_width", self.core.issue_width),
            ("core.rob", self.core.rob),
            ("core.lsq", self.core.lsq),
            ("core.mshrs", self.core.mshrs),
        ] {
            if v == 0 {
                return Err(SpecError::BadValue(format!("{what} must be >= 1")));
            }
        }
        for (what, v) in [
            ("dram.vaults", self.dram.vaults),
            ("dram.banks_per_vault", self.dram.banks_per_vault),
            ("dram.row_bytes", self.dram.row_bytes),
            ("dram.line_bytes", self.dram.line_bytes),
        ] {
            if v == 0 {
                return Err(SpecError::BadValue(format!("{what} must be >= 1")));
            }
        }
        for (what, v) in [
            ("dram.host_peak_bw", self.dram.host_peak_bw),
            ("dram.ndp_peak_bw", self.dram.ndp_peak_bw),
        ] {
            if !(v.is_finite() && v > 0.0) {
                return Err(SpecError::BadValue(format!("{what} must be > 0")));
            }
        }
        Ok(())
    }

    /// Canonical JSON form: every field written out explicitly, so
    /// serialize → parse is the identity and [`fingerprint`] is
    /// spelling-invariant.
    ///
    /// [`fingerprint`]: SystemSpec::fingerprint
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", self.name.as_str());
        j.set("backend", self.backend.label());
        j.set("l1_read_only", self.l1_read_only);
        let mut core = Json::obj();
        core.set("freq_hz", self.core.freq_hz)
            .set("issue_width", self.core.issue_width)
            .set("rob", self.core.rob)
            .set("lsq", self.core.lsq)
            .set("mshrs", self.core.mshrs);
        j.set("core", core);
        let caches: Vec<Json> = self
            .caches
            .iter()
            .map(|l| {
                let mut jl = Json::obj();
                jl.set("size_bytes", l.size_bytes)
                    .set("ways", l.ways)
                    .set("line_bytes", l.line_bytes)
                    .set("latency_cycles", l.latency_cycles)
                    .set("epj_hit", l.epj_hit)
                    .set("epj_miss", l.epj_miss)
                    .set("shared", l.shared)
                    .set("banks", l.banks)
                    .set("scale_with_cores", l.scale_with_cores);
                jl
            })
            .collect();
        j.set("caches", Json::Arr(caches));
        match &self.prefetcher {
            Some(p) => {
                let mut jp = Json::obj();
                jp.set("streams", p.streams).set("degree", p.degree);
                j.set("prefetcher", jp);
            }
            None => {
                j.set("prefetcher", Json::Null);
            }
        }
        let mut dram = Json::obj();
        dram.set("vaults", self.dram.vaults)
            .set("banks_per_vault", self.dram.banks_per_vault)
            .set("row_bytes", self.dram.row_bytes)
            .set("line_bytes", self.dram.line_bytes)
            .set("row_hit_cycles", self.dram.row_hit_cycles)
            .set("act_cycles", self.dram.act_cycles)
            .set("pre_act_cycles", self.dram.pre_act_cycles)
            .set("host_link_cycles", self.dram.host_link_cycles)
            .set("host_peak_bw", self.dram.host_peak_bw)
            .set("ndp_peak_bw", self.dram.ndp_peak_bw)
            .set("epj_bit_internal", self.dram.epj_bit_internal)
            .set("epj_bit_logic", self.dram.epj_bit_logic)
            .set("epj_bit_link", self.dram.epj_bit_link);
        j.set("dram", dram);
        let mut noc = Json::obj();
        noc.set("cycles_per_hop", self.noc.cycles_per_hop)
            .set("epj_router", self.noc.epj_router)
            .set("epj_link", self.noc.epj_link);
        j.set("noc", noc);
        j
    }

    /// Parse and validate a spec from a JSON value. Strict: unknown
    /// fields anywhere are errors, so a typo'd key can never silently
    /// fall back to a default.
    pub fn from_json(j: &Json) -> Result<SystemSpec, SpecError> {
        let obj = as_obj(j, "system spec")?;
        check_fields(
            obj,
            "",
            &[
                "name",
                "backend",
                "l1_read_only",
                "core",
                "caches",
                "prefetcher",
                "dram",
                "noc",
            ],
        )?;
        let name = j
            .get("name")
            .ok_or_else(|| SpecError::MissingField("name".to_string()))?
            .as_str()
            .ok_or_else(|| SpecError::BadValue("name must be a string".to_string()))?
            .to_string();
        let backend = match j.get("backend") {
            None => MemoryBackend::HmcLink,
            Some(b) => {
                let s = b
                    .as_str()
                    .ok_or_else(|| SpecError::BadValue("backend must be a string".to_string()))?;
                MemoryBackend::parse(s).ok_or_else(|| {
                    SpecError::BadValue(format!(
                        "backend {s:?} (expected hmc-link, direct-vault or nuca-mesh)"
                    ))
                })?
            }
        };
        let l1_read_only = opt_bool(j, "", "l1_read_only")?.unwrap_or(false);
        let core = match j.get("core") {
            None => CoreParams::default(),
            Some(c) => {
                let cobj = as_obj(c, "core")?;
                check_fields(cobj, "core.", &["freq_hz", "issue_width", "rob", "lsq", "mshrs"])?;
                let d = CoreParams::default();
                CoreParams {
                    freq_hz: opt_f64(c, "core", "freq_hz")?.unwrap_or(d.freq_hz),
                    issue_width: opt_u64(c, "core", "issue_width")?.unwrap_or(d.issue_width),
                    rob: opt_u64(c, "core", "rob")?.unwrap_or(d.rob),
                    lsq: opt_u64(c, "core", "lsq")?.unwrap_or(d.lsq),
                    mshrs: opt_u64(c, "core", "mshrs")?.unwrap_or(d.mshrs),
                }
            }
        };
        let caches_json = j
            .get("caches")
            .ok_or_else(|| SpecError::MissingField("caches".to_string()))?
            .as_arr()
            .ok_or_else(|| SpecError::BadValue("caches must be an array".to_string()))?;
        let mut caches = Vec::with_capacity(caches_json.len());
        for (i, jl) in caches_json.iter().enumerate() {
            let section = format!("caches[{i}]");
            let lobj = as_obj(jl, &section)?;
            check_fields(
                lobj,
                &format!("{section}."),
                &[
                    "size_bytes",
                    "ways",
                    "line_bytes",
                    "latency_cycles",
                    "epj_hit",
                    "epj_miss",
                    "shared",
                    "banks",
                    "scale_with_cores",
                ],
            )?;
            caches.push(CacheLevelSpec {
                size_bytes: req_usize(jl, &section, "size_bytes")?,
                ways: req_usize(jl, &section, "ways")?,
                line_bytes: opt_usize(jl, &section, "line_bytes")?.unwrap_or(LINE),
                latency_cycles: req_u64(jl, &section, "latency_cycles")?,
                epj_hit: req_f64(jl, &section, "epj_hit")?,
                epj_miss: req_f64(jl, &section, "epj_miss")?,
                shared: opt_bool(jl, &section, "shared")?.unwrap_or(false),
                banks: opt_usize(jl, &section, "banks")?.unwrap_or(16),
                scale_with_cores: opt_bool(jl, &section, "scale_with_cores")?.unwrap_or(false),
            });
        }
        let prefetcher = match j.get("prefetcher") {
            None | Some(Json::Null) => None,
            Some(p) => {
                let pobj = as_obj(p, "prefetcher")?;
                check_fields(pobj, "prefetcher.", &["streams", "degree"])?;
                Some(PrefetcherSpec {
                    streams: opt_usize(p, "prefetcher", "streams")?.unwrap_or(16),
                    degree: opt_usize(p, "prefetcher", "degree")?.unwrap_or(2),
                })
            }
        };
        let dram = match j.get("dram") {
            None => DramConfig::default(),
            Some(d) => {
                let dobj = as_obj(d, "dram")?;
                check_fields(
                    dobj,
                    "dram.",
                    &[
                        "vaults",
                        "banks_per_vault",
                        "row_bytes",
                        "line_bytes",
                        "row_hit_cycles",
                        "act_cycles",
                        "pre_act_cycles",
                        "host_link_cycles",
                        "host_peak_bw",
                        "ndp_peak_bw",
                        "epj_bit_internal",
                        "epj_bit_logic",
                        "epj_bit_link",
                    ],
                )?;
                let def = DramConfig::default();
                DramConfig {
                    vaults: opt_usize(d, "dram", "vaults")?.unwrap_or(def.vaults),
                    banks_per_vault: opt_usize(d, "dram", "banks_per_vault")?
                        .unwrap_or(def.banks_per_vault),
                    row_bytes: opt_usize(d, "dram", "row_bytes")?.unwrap_or(def.row_bytes),
                    line_bytes: opt_usize(d, "dram", "line_bytes")?.unwrap_or(def.line_bytes),
                    row_hit_cycles: opt_u64(d, "dram", "row_hit_cycles")?
                        .unwrap_or(def.row_hit_cycles),
                    act_cycles: opt_u64(d, "dram", "act_cycles")?.unwrap_or(def.act_cycles),
                    pre_act_cycles: opt_u64(d, "dram", "pre_act_cycles")?
                        .unwrap_or(def.pre_act_cycles),
                    host_link_cycles: opt_u64(d, "dram", "host_link_cycles")?
                        .unwrap_or(def.host_link_cycles),
                    host_peak_bw: opt_f64(d, "dram", "host_peak_bw")?.unwrap_or(def.host_peak_bw),
                    ndp_peak_bw: opt_f64(d, "dram", "ndp_peak_bw")?.unwrap_or(def.ndp_peak_bw),
                    epj_bit_internal: opt_f64(d, "dram", "epj_bit_internal")?
                        .unwrap_or(def.epj_bit_internal),
                    epj_bit_logic: opt_f64(d, "dram", "epj_bit_logic")?
                        .unwrap_or(def.epj_bit_logic),
                    epj_bit_link: opt_f64(d, "dram", "epj_bit_link")?.unwrap_or(def.epj_bit_link),
                }
            }
        };
        let noc = match j.get("noc") {
            None => NocConfig::default(),
            Some(n) => {
                let nobj = as_obj(n, "noc")?;
                check_fields(nobj, "noc.", &["cycles_per_hop", "epj_router", "epj_link"])?;
                let def = NocConfig::default();
                NocConfig {
                    cycles_per_hop: opt_u64(n, "noc", "cycles_per_hop")?
                        .unwrap_or(def.cycles_per_hop),
                    epj_router: opt_f64(n, "noc", "epj_router")?.unwrap_or(def.epj_router),
                    epj_link: opt_f64(n, "noc", "epj_link")?.unwrap_or(def.epj_link),
                }
            }
        };
        let spec = SystemSpec {
            name,
            core,
            caches,
            l1_read_only,
            prefetcher,
            backend,
            dram,
            noc,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Parse and validate a spec from JSON text.
    pub fn from_json_str(text: &str) -> Result<SystemSpec, SpecError> {
        let j = Json::parse(text).map_err(SpecError::Parse)?;
        SystemSpec::from_json(&j)
    }

    /// Load and validate a spec from a JSON file.
    pub fn load(path: &Path) -> Result<SystemSpec, SpecError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| SpecError::Io(format!("{}: {e}", path.display())))?;
        SystemSpec::from_json_str(&text)
    }

    /// Start composing a spec inline (defaults: Table 1 core/DRAM/NoC,
    /// HMC-link backend, no caches — add levels innermost-first).
    pub fn builder(name: &str) -> SystemSpecBuilder {
        SystemSpecBuilder {
            spec: SystemSpec {
                name: name.to_string(),
                core: CoreParams::default(),
                caches: Vec::new(),
                l1_read_only: false,
                prefetcher: None,
                backend: MemoryBackend::HmcLink,
                dram: DramConfig::default(),
                noc: NocConfig::default(),
            },
        }
    }
}

fn validate_geometry(i: usize, l: &CacheLevelSpec) -> Result<(), SpecError> {
    if l.size_bytes == 0 || l.ways == 0 || l.line_bytes == 0 {
        return Err(SpecError::Geometry(format!(
            "caches[{i}]: size_bytes, ways and line_bytes must all be >= 1"
        )));
    }
    if !l.line_bytes.is_power_of_two() {
        return Err(SpecError::Geometry(format!(
            "caches[{i}]: line_bytes {} is not a power of two",
            l.line_bytes
        )));
    }
    if l.size_bytes % (l.line_bytes * l.ways) != 0 {
        return Err(SpecError::Geometry(format!(
            "caches[{i}]: size {} is not divisible by line_bytes*ways = {}",
            l.size_bytes,
            l.line_bytes * l.ways
        )));
    }
    let sets = l.size_bytes / l.line_bytes / l.ways;
    if sets == 0 || !sets.is_power_of_two() {
        return Err(SpecError::Geometry(format!(
            "caches[{i}]: set count {sets} (size {} / line {} / ways {}) must be a \
             non-zero power of two",
            l.size_bytes, l.line_bytes, l.ways
        )));
    }
    Ok(())
}

/// Fluent inline composition of a [`SystemSpec`] (used by examples and
/// design-space studies; `build()` runs full validation).
pub struct SystemSpecBuilder {
    spec: SystemSpec,
}

impl SystemSpecBuilder {
    pub fn backend(mut self, backend: MemoryBackend) -> Self {
        self.spec.backend = backend;
        self
    }

    pub fn read_only_l1(mut self, read_only: bool) -> Self {
        self.spec.l1_read_only = read_only;
        self
    }

    pub fn core(mut self, core: CoreParams) -> Self {
        self.spec.core = core;
        self
    }

    /// Append a private cache level (innermost first).
    pub fn private_cache(
        mut self,
        size_bytes: usize,
        ways: usize,
        latency_cycles: u64,
        epj_hit: f64,
        epj_miss: f64,
    ) -> Self {
        self.spec.caches.push(CacheLevelSpec {
            size_bytes,
            ways,
            line_bytes: LINE,
            latency_cycles,
            epj_hit,
            epj_miss,
            shared: false,
            banks: 16,
            scale_with_cores: false,
        });
        self
    }

    /// Append the shared LLC level (must come last).
    pub fn shared_cache(
        mut self,
        size_bytes: usize,
        ways: usize,
        latency_cycles: u64,
        epj_hit: f64,
        epj_miss: f64,
        banks: usize,
    ) -> Self {
        self.spec.caches.push(CacheLevelSpec {
            size_bytes,
            ways,
            line_bytes: LINE,
            latency_cycles,
            epj_hit,
            epj_miss,
            shared: true,
            banks,
            scale_with_cores: false,
        });
        self
    }

    pub fn prefetcher(mut self, streams: usize, degree: usize) -> Self {
        self.spec.prefetcher = Some(PrefetcherSpec { streams, degree });
        self
    }

    pub fn dram(mut self, dram: DramConfig) -> Self {
        self.spec.dram = dram;
        self
    }

    pub fn noc(mut self, noc: NocConfig) -> Self {
        self.spec.noc = noc;
        self
    }

    /// Validate and return the finished spec.
    pub fn build(self) -> Result<SystemSpec, SpecError> {
        self.spec.validate()?;
        Ok(self.spec)
    }
}

fn as_obj<'a>(
    j: &'a Json,
    what: &str,
) -> Result<&'a std::collections::BTreeMap<String, Json>, SpecError> {
    match j {
        Json::Obj(m) => Ok(m),
        _ => Err(SpecError::BadValue(format!("{what} must be a JSON object"))),
    }
}

fn check_fields(
    obj: &std::collections::BTreeMap<String, Json>,
    prefix: &str,
    allowed: &[&str],
) -> Result<(), SpecError> {
    for key in obj.keys() {
        if !allowed.contains(&key.as_str()) {
            return Err(SpecError::UnknownField(format!("{prefix}{key}")));
        }
    }
    Ok(())
}

fn get_num(j: &Json, section: &str, key: &str) -> Result<Option<f64>, SpecError> {
    match j.get(key) {
        None => Ok(None),
        Some(v) => {
            let x = v.as_f64().ok_or_else(|| {
                SpecError::BadValue(format!("{section}.{key} must be a number"))
            })?;
            if !x.is_finite() {
                return Err(SpecError::BadValue(format!(
                    "{section}.{key} must be finite"
                )));
            }
            Ok(Some(x))
        }
    }
}

fn opt_f64(j: &Json, section: &str, key: &str) -> Result<Option<f64>, SpecError> {
    get_num(j, section, key)
}

fn opt_int(j: &Json, section: &str, key: &str) -> Result<Option<u64>, SpecError> {
    match get_num(j, section, key)? {
        None => Ok(None),
        Some(x) => {
            if x < 0.0 || x.fract() != 0.0 || x >= 9e15 {
                return Err(SpecError::BadValue(format!(
                    "{section}.{key} must be a non-negative integer, got {x}"
                )));
            }
            Ok(Some(x as u64))
        }
    }
}

fn opt_u64(j: &Json, section: &str, key: &str) -> Result<Option<u64>, SpecError> {
    opt_int(j, section, key)
}

fn opt_usize(j: &Json, section: &str, key: &str) -> Result<Option<usize>, SpecError> {
    Ok(opt_int(j, section, key)?.map(|x| x as usize))
}

fn opt_bool(j: &Json, section: &str, key: &str) -> Result<Option<bool>, SpecError> {
    match j.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_bool()
            .map(Some)
            .ok_or_else(|| SpecError::BadValue(format!("{section}{}{key} must be a boolean",
                if section.is_empty() { "" } else { "." }))),
    }
}

fn req_of<T>(
    section: &str,
    key: &str,
    v: Option<T>,
) -> Result<T, SpecError> {
    v.ok_or_else(|| SpecError::MissingField(format!("{section}.{key}")))
}

fn req_usize(j: &Json, section: &str, key: &str) -> Result<usize, SpecError> {
    req_of(section, key, opt_usize(j, section, key)?)
}

fn req_u64(j: &Json, section: &str, key: &str) -> Result<u64, SpecError> {
    req_of(section, key, opt_u64(j, section, key)?)
}

fn req_f64(j: &Json, section: &str, key: &str) -> Result<f64, SpecError> {
    req_of(section, key, opt_f64(j, section, key)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate_and_lower_to_table1() {
        for spec in SystemSpec::presets() {
            spec.validate().unwrap_or_else(|e| panic!("{}: {e}", spec.name));
        }
        let h = SystemSpec::host().build(4, CoreModel::OutOfOrder);
        assert_eq!(h.label, "host");
        assert_eq!(h.backend, MemoryBackend::HmcLink);
        assert!(!h.l1_read_only && !h.prefetch);
        assert_eq!(h.l1.sets(), 64);
        assert_eq!(h.l2.unwrap().sets(), 512);
        assert_eq!(h.l3.unwrap().sets(), 8192);
        assert_eq!(h.l3_banks, 16);

        let pf = SystemSpec::host_prefetch().build(4, CoreModel::OutOfOrder);
        assert!(pf.prefetch && pf.pf_streams == 16 && pf.pf_degree == 2);

        let n = SystemSpec::ndp().build(16, CoreModel::InOrder);
        assert_eq!(n.backend, MemoryBackend::DirectVault);
        assert!(n.l1_read_only && n.l2.is_none() && n.l3.is_none());

        let nuca = SystemSpec::host_nuca().build(256, CoreModel::OutOfOrder);
        assert_eq!(nuca.l3.unwrap().size_bytes, 512 << 20);
        assert_eq!(nuca.l3_banks, 256);
        assert_eq!(nuca.backend, MemoryBackend::NucaMesh);
    }

    #[test]
    fn json_roundtrip_is_identity_for_presets() {
        for spec in SystemSpec::presets() {
            let back = SystemSpec::from_json(&spec.to_json())
                .unwrap_or_else(|e| panic!("{}: {e}", spec.name));
            assert_eq!(back, spec, "{} drifted through JSON", spec.name);
            assert_eq!(back.fingerprint(), spec.fingerprint());
        }
    }

    #[test]
    fn sparse_json_fills_table1_defaults() {
        let text = r#"{
            "name": "mini",
            "caches": [
                {"size_bytes": 16384, "ways": 4, "latency_cycles": 3,
                 "epj_hit": 10.0, "epj_miss": 20.0}
            ]
        }"#;
        let spec = SystemSpec::from_json_str(text).unwrap();
        assert_eq!(spec.backend, MemoryBackend::HmcLink);
        assert_eq!(spec.core, CoreParams::default());
        assert_eq!(spec.caches[0].line_bytes, LINE);
        assert_eq!(spec.dram, DramConfig::default());
        // Sparse and explicit spellings of the same system fingerprint
        // identically (the canonical form is hashed, not the source).
        let respelled = SystemSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(spec.fingerprint(), respelled.fingerprint());
    }

    #[test]
    fn unknown_fields_are_rejected_everywhere() {
        let top = r#"{"name":"x","caches":[],"sizebytes":1}"#;
        assert!(matches!(
            SystemSpec::from_json_str(top),
            Err(SpecError::UnknownField(f)) if f == "sizebytes"
        ));
        let nested = r#"{
            "name": "x",
            "caches": [{"size_bytes": 16384, "ways": 4, "latency_cycles": 3,
                        "epj_hit": 1.0, "epj_miss": 2.0, "wayz": 8}]
        }"#;
        assert!(matches!(
            SystemSpec::from_json_str(nested),
            Err(SpecError::UnknownField(f)) if f == "caches[0].wayz"
        ));
    }

    #[test]
    fn structural_rules_are_enforced() {
        assert!(matches!(
            SystemSpec::from_json_str(r#"{"name":"x","caches":[]}"#),
            Err(SpecError::EmptyHierarchy)
        ));
        // Non-power-of-two set count.
        let bad_geom = r#"{
            "name": "x",
            "caches": [{"size_bytes": 24576, "ways": 4, "latency_cycles": 3,
                        "epj_hit": 1.0, "epj_miss": 2.0}]
        }"#;
        assert!(matches!(
            SystemSpec::from_json_str(bad_geom),
            Err(SpecError::Geometry(_))
        ));
        // Degenerate geometry that used to divide sets to 0 and panic
        // later in Cache::new now fails validation up front.
        let zero_sets = CacheLevelSpec {
            size_bytes: 32,
            ways: 8,
            ..l1_level()
        };
        assert!(matches!(
            validate_geometry(0, &zero_sets),
            Err(SpecError::Geometry(_))
        ));
        // Shared level must be last.
        let mut s = SystemSpec::host();
        s.caches.swap(1, 2);
        assert!(matches!(s.validate(), Err(SpecError::Hierarchy(_))));
        // Prefetcher needs a private L2.
        let mut p = SystemSpec::ndp();
        p.prefetcher = Some(PrefetcherSpec { streams: 16, degree: 2 });
        assert!(matches!(p.validate(), Err(SpecError::Hierarchy(_))));
        // Missing required field.
        assert!(matches!(
            SystemSpec::from_json_str(r#"{"caches":[]}"#),
            Err(SpecError::MissingField(f)) if f == "name"
        ));
    }

    #[test]
    fn builder_composes_valid_specs() {
        let spec = SystemSpec::builder("ndp-l1-64k")
            .backend(MemoryBackend::DirectVault)
            .read_only_l1(true)
            .private_cache(64 << 10, 8, 4, 15.0, 33.0)
            .build()
            .unwrap();
        assert_eq!(spec.name, "ndp-l1-64k");
        let cfg = spec.build(16, CoreModel::OutOfOrder);
        assert_eq!(cfg.l1.size_bytes, 64 << 10);
        assert!(cfg.l1_read_only && cfg.l2.is_none());

        // Builder surfaces validation errors instead of panicking later.
        let bad = SystemSpec::builder("bad").build();
        assert!(matches!(bad, Err(SpecError::EmptyHierarchy)));
    }

    #[test]
    fn distinct_specs_never_share_a_fingerprint() {
        let mut names = std::collections::BTreeSet::new();
        for s in SystemSpec::presets() {
            assert!(names.insert(s.fingerprint()), "{} collided", s.name);
        }
        let mut tweaked = SystemSpec::host();
        tweaked.caches[0].size_bytes = 64 << 10;
        assert!(names.insert(tweaked.fingerprint()), "tweaked spec collided");
    }
}
