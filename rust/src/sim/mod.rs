//! DAMOV-SIM substrate: trace-driven multicore memory-hierarchy simulator
//! (substitutes ZSim + Ramulator; see DESIGN.md §1 and §3 for the model
//! and its validity argument).

pub mod accel;
pub mod cache;
pub mod config;
pub mod dram;
pub mod energy;
pub mod engine;
pub mod events;
pub mod noc;
pub mod prefetcher;
pub mod spec;

pub use config::{CoreModel, MemoryBackend, SystemConfig, CORE_SWEEP, LINE};
pub use engine::{simulate, simulate_events, SimResult};
pub use spec::{SpecError, SystemSpec};
pub use events::{SoaTrace, TraceAnalysis};

/// One memory reference in a workload trace.
///
/// `gap` counts non-memory instructions executed since the previous
/// access (drives IPC and the ROB-window MLP estimate); `ops` counts the
/// arithmetic/logic operations attributed to this access (drives AI);
/// `dep` marks loads whose *address* depends on the previous load's data
/// (pointer chasing — these can never overlap in the core).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Access {
    pub addr: u64,
    pub write: bool,
    pub dep: bool,
    /// Static basic-block id of the instruction issuing this access
    /// (drives the Fig 24/25 fine-grained-offload case study).
    pub bb: u8,
    pub gap: u16,
    pub ops: u16,
}

impl Access {
    pub fn load(addr: u64, gap: u16, ops: u16) -> Access {
        Access {
            addr,
            write: false,
            dep: false,
            bb: 0,
            gap,
            ops,
        }
    }

    pub fn load_dep(addr: u64, gap: u16, ops: u16) -> Access {
        Access {
            addr,
            write: false,
            dep: true,
            bb: 0,
            gap,
            ops,
        }
    }

    pub fn store(addr: u64, gap: u16, ops: u16) -> Access {
        Access {
            addr,
            write: true,
            dep: false,
            bb: 0,
            gap,
            ops,
        }
    }

    /// Tag with a basic-block id.
    pub fn in_bb(mut self, bb: u8) -> Access {
        self.bb = bb;
        self
    }
}

/// A multi-threaded trace: one access stream per simulated core.
pub type Trace = Vec<Vec<Access>>;
