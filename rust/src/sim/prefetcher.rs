//! L2 stream prefetcher (Table 1: stream prefetcher, 2-degree, 16 stream
//! buffers, 64 entries — after Palacharla & Kessler / Srinath et al.).
//!
//! Trained on the L1-miss stream (i.e., L2 accesses), per core. A stream
//! allocates after two misses with matching direction within a small
//! window, then issues `degree` prefetches ahead of the demand stream and
//! advances as demand catches up. Useless prefetches (never demanded
//! before eviction) are tracked so the engine can charge wasted DRAM
//! bandwidth — the mechanism by which prefetching *hurts* class-1a
//! workloads in the paper (§3.3.1).

#[derive(Debug, Clone, Copy)]
struct Stream {
    /// Next line expected to be demanded.
    next_line: u64,
    /// +1 or -1 lines.
    dir: i64,
    /// Lines prefetched ahead but not yet demanded.
    ahead: u64,
    /// LRU stamp.
    last_used: u64,
    valid: bool,
}

pub struct StreamPrefetcher {
    streams: Vec<Stream>,
    degree: u64,
    tick: u64,
    /// Lines issued as prefetches.
    pub issued: u64,
    /// Demand accesses that matched a tracked stream (proxy for accuracy).
    pub useful: u64,
}

impl StreamPrefetcher {
    pub fn new(n_streams: usize, degree: usize) -> StreamPrefetcher {
        StreamPrefetcher {
            streams: vec![
                Stream {
                    next_line: 0,
                    dir: 1,
                    ahead: 0,
                    last_used: 0,
                    valid: false
                };
                n_streams
            ],
            degree: degree as u64,
            tick: 0,
            issued: 0,
            useful: 0,
        }
    }

    /// Observe a demand L2 access for `line` (line address, i.e.
    /// `addr / 64`). Returns lines to prefetch (absolute line addresses).
    pub fn observe(&mut self, line: u64) -> Vec<u64> {
        self.tick += 1;
        let mut out = Vec::new();
        // 1) Does this demand hit a tracked stream head?
        for s in self.streams.iter_mut() {
            if !s.valid {
                continue;
            }
            if line == s.next_line {
                self.useful += 1;
                s.last_used = self.tick;
                s.next_line = (s.next_line as i64 + s.dir) as u64;
                if s.ahead > 0 {
                    s.ahead -= 1;
                }
                // Keep `degree` lines of runway ahead of demand.
                while s.ahead < self.degree {
                    let pf = (s.next_line as i64 + s.ahead as i64 * s.dir) as u64;
                    out.push(pf);
                    s.ahead += 1;
                    self.issued += 1;
                }
                return out;
            }
        }
        // 2) Train: a miss adjacent (±1 line) to a recent miss allocates a
        // stream. We keep a tiny shadow of the last few misses in the
        // stream table itself: reuse an invalid slot to record this line as
        // a "candidate" stream with 0 runway.
        for s in self.streams.iter_mut() {
            if s.valid && s.ahead == 0 && (line as i64 - (s.next_line as i64 - s.dir)).abs() == 1 {
                // Direction confirmed relative to candidate origin.
                s.dir = if line as i64 > s.next_line as i64 - s.dir { 1 } else { -1 };
                s.next_line = (line as i64 + s.dir) as u64;
                s.last_used = self.tick;
                while s.ahead < self.degree {
                    let pf = (line as i64 + (s.ahead as i64 + 1) * s.dir) as u64;
                    out.push(pf);
                    s.ahead += 1;
                    self.issued += 1;
                }
                return out;
            }
        }
        // 3) Allocate a candidate in the LRU slot.
        let slot = self
            .streams
            .iter()
            .enumerate()
            .min_by_key(|(_, s)| if s.valid { s.last_used } else { 0 })
            .map(|(i, _)| i)
            .unwrap_or(0);
        self.streams[slot] = Stream {
            next_line: line + 1,
            dir: 1,
            ahead: 0,
            last_used: self.tick,
            valid: true,
        };
        out
    }

    /// Fraction of issued prefetches that matched later demand. 1.0 if
    /// nothing was issued.
    pub fn accuracy(&self) -> f64 {
        if self.issued == 0 {
            1.0
        } else {
            (self.useful as f64 / self.issued as f64).min(1.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_stream_triggers_prefetches() {
        let mut pf = StreamPrefetcher::new(16, 2);
        let mut issued = 0;
        for line in 100..200u64 {
            issued += pf.observe(line).len();
        }
        assert!(issued >= 90, "issued={issued}");
        assert!(pf.accuracy() > 0.8, "accuracy={}", pf.accuracy());
    }

    #[test]
    fn random_misses_issue_little() {
        let mut pf = StreamPrefetcher::new(16, 2);
        let mut rng = crate::util::rng::Xoshiro256::new(5);
        let mut issued = 0;
        for _ in 0..1000 {
            issued += pf.observe(rng.gen_range(1 << 30)).len();
        }
        // Random lines almost never form adjacent pairs.
        assert!(issued < 50, "issued={issued}");
    }

    #[test]
    fn descending_stream_detected() {
        let mut pf = StreamPrefetcher::new(16, 2);
        let mut issued = 0;
        for i in 0..100u64 {
            issued += pf.observe(5000 - i).len();
        }
        assert!(issued >= 50, "issued={issued}");
    }

    #[test]
    fn interleaved_streams_tracked_independently() {
        let mut pf = StreamPrefetcher::new(16, 2);
        let mut issued = 0;
        for i in 0..100u64 {
            issued += pf.observe(1000 + i).len();
            issued += pf.observe(900_000 + i).len();
        }
        assert!(issued >= 150, "issued={issued}");
        assert!(pf.accuracy() > 0.7);
    }

    #[test]
    fn runway_is_bounded_by_degree() {
        let mut pf = StreamPrefetcher::new(4, 2);
        for i in 0..50u64 {
            let pfs = pf.observe(i);
            assert!(pfs.len() <= 3, "burst of {}", pfs.len());
        }
    }
}
