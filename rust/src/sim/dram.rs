//! HMC-like main memory model (substitutes Ramulator).
//!
//! Geometry per Table 1: 32 vaults × 8 banks/vault, 256 B row buffers,
//! open-page policy, HMC default Row:Column:Bank:Vault interleaving (so
//! consecutive cache lines stripe across vaults first, then banks, then
//! columns within a row).
//!
//! The model tracks per-(vault,bank) open rows to classify each access as
//! a row **hit** (CAS only), **miss** (activate) or **conflict**
//! (precharge + activate), yielding an unloaded service latency. Loaded
//! latency (queuing at the memory controller / link) is applied later by
//! the timing fixed point in `engine.rs` using an M/D/1 waiting-time term,
//! which is how ZSim++'s network model treats contention as well.

use super::config::DramConfig;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RowOutcome {
    Hit,
    Miss,
    Conflict,
}

#[derive(Debug, Default, Clone)]
pub struct DramStats {
    pub reads: u64,
    pub writes: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    pub row_conflicts: u64,
    /// Per-vault access counts (drives the NDP NoC case study + balance).
    pub vault_accesses: Vec<u64>,
}

pub struct Dram {
    cfg: DramConfig,
    /// Open row per (vault, bank); u64::MAX = closed.
    open_row: Vec<u64>,
    /// Last bank touched per vault — a same-bank different-row access is a
    /// conflict; a different-bank access with a closed row is a plain miss.
    pub stats: DramStats,
    line_shift: u32,
    vault_mask: u64,
    vault_bits: u32,
    bank_mask: u64,
    bank_bits: u32,
    col_bits: u32,
}

impl Dram {
    pub fn new(cfg: &DramConfig) -> Dram {
        assert!(cfg.vaults.is_power_of_two());
        assert!(cfg.banks_per_vault.is_power_of_two());
        let lines_per_row = (cfg.row_bytes / cfg.line_bytes).max(1);
        Dram {
            cfg: *cfg,
            open_row: vec![u64::MAX; cfg.vaults * cfg.banks_per_vault],
            stats: DramStats {
                vault_accesses: vec![0; cfg.vaults],
                ..Default::default()
            },
            line_shift: cfg.line_bytes.trailing_zeros(),
            vault_mask: (cfg.vaults - 1) as u64,
            vault_bits: cfg.vaults.trailing_zeros(),
            bank_mask: (cfg.banks_per_vault - 1) as u64,
            bank_bits: cfg.banks_per_vault.trailing_zeros(),
            col_bits: lines_per_row.trailing_zeros(),
        }
    }

    /// HMC default interleave: line address bits are, from LSB:
    /// [vault][bank][column][row...].
    #[inline]
    pub fn decode(&self, addr: u64) -> (usize, usize, u64) {
        let line = addr >> self.line_shift;
        let vault = (line & self.vault_mask) as usize;
        let bank = ((line >> self.vault_bits) & self.bank_mask) as usize;
        let row = line >> (self.vault_bits + self.bank_bits + self.col_bits);
        (vault, bank, row)
    }

    #[inline]
    pub fn vault_of(&self, addr: u64) -> usize {
        ((addr >> self.line_shift) & self.vault_mask) as usize
    }

    /// Service one line access; returns (outcome, unloaded service cycles
    /// at the vault — excludes off-chip link and queuing).
    pub fn access(&mut self, addr: u64, write: bool) -> (RowOutcome, u64) {
        let (vault, bank, row) = self.decode(addr);
        let slot = vault * self.cfg.banks_per_vault + bank;
        let open = self.open_row[slot];
        let outcome = if open == row {
            RowOutcome::Hit
        } else if open == u64::MAX {
            RowOutcome::Miss
        } else {
            RowOutcome::Conflict
        };
        self.open_row[slot] = row;
        self.stats.vault_accesses[vault] += 1;
        if write {
            self.stats.writes += 1;
        } else {
            self.stats.reads += 1;
        }
        let lat = match outcome {
            RowOutcome::Hit => {
                self.stats.row_hits += 1;
                self.cfg.row_hit_cycles
            }
            RowOutcome::Miss => {
                self.stats.row_misses += 1;
                self.cfg.row_hit_cycles + self.cfg.act_cycles
            }
            RowOutcome::Conflict => {
                self.stats.row_conflicts += 1;
                self.cfg.row_hit_cycles + self.cfg.pre_act_cycles
            }
        };
        (outcome, lat)
    }

    /// Mean unloaded service latency observed so far (cycles).
    pub fn mean_service_cycles(&self) -> f64 {
        let n = (self.stats.row_hits + self.stats.row_misses + self.stats.row_conflicts).max(1);
        let total = self.stats.row_hits * self.cfg.row_hit_cycles
            + self.stats.row_misses * (self.cfg.row_hit_cycles + self.cfg.act_cycles)
            + self.stats.row_conflicts * (self.cfg.row_hit_cycles + self.cfg.pre_act_cycles);
        total as f64 / n as f64
    }

    /// Load-balance metric across vaults: max/mean access ratio (1.0 =
    /// perfectly balanced). Used by case study 1.
    pub fn vault_imbalance(&self) -> f64 {
        let max = self.stats.vault_accesses.iter().copied().max().unwrap_or(0) as f64;
        let mean = self.stats.vault_accesses.iter().sum::<u64>() as f64
            / self.stats.vault_accesses.len().max(1) as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }
}

/// M/D/1 mean waiting time (in the same unit as `service`), given
/// utilization `rho` in [0,1). Clamped below saturation so the fixed
/// point in the engine converges; the clamp region is reported by the
/// engine as "queue-full reissue" pressure (paper §3.3.4 observes
/// controller-queue reissues at 256 cores).
pub fn md1_wait(service: f64, rho: f64) -> f64 {
    let rho = rho.clamp(0.0, 0.98);
    service * rho / (2.0 * (1.0 - rho))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::SystemConfig;
    use crate::sim::config::CoreModel;

    fn dram() -> Dram {
        Dram::new(&SystemConfig::host(1, CoreModel::OutOfOrder).dram)
    }

    #[test]
    fn decode_interleaves_vault_first() {
        let d = dram();
        let (v0, b0, r0) = d.decode(0);
        let (v1, b1, r1) = d.decode(64);
        assert_eq!((v0, b0, r0), (0, 0, 0));
        assert_eq!((v1, b1), (1, 0));
        assert_eq!(r1, 0);
        // After 32 lines we wrap to vault 0, bank 1.
        let (v32, b32, _) = d.decode(32 * 64);
        assert_eq!((v32, b32), (0, 1));
        // After 32*8=256 lines: vault 0, bank 0, column 1 (same row 0).
        let (v, b, r) = d.decode(256 * 64);
        assert_eq!((v, b, r), (0, 0, 0));
        // After 1024 lines (4 columns * 256): row increments.
        let (_, _, r) = d.decode(1024 * 64);
        assert_eq!(r, 1);
    }

    #[test]
    fn row_hit_miss_conflict_sequence() {
        let mut d = dram();
        // First touch: bank closed -> miss.
        let (o1, l1) = d.access(0, false);
        assert_eq!(o1, RowOutcome::Miss);
        // Same row (column 1 of row 0 in vault0/bank0 = line 256).
        let (o2, l2) = d.access(256 * 64, false);
        assert_eq!(o2, RowOutcome::Hit);
        assert!(l2 < l1);
        // Different row, same bank -> conflict.
        let (o3, l3) = d.access(1024 * 64, false);
        assert_eq!(o3, RowOutcome::Conflict);
        assert!(l3 > l1);
    }

    #[test]
    fn sequential_stream_mostly_hits_after_warmup() {
        let mut d = dram();
        for i in 0..8192u64 {
            d.access(i * 64, false);
        }
        let s = &d.stats;
        // 256 (vault,bank) pairs activate once (miss/conflict), then hit.
        assert!(s.row_hits > 6000, "row_hits={}", s.row_hits);
    }

    #[test]
    fn random_accesses_mostly_conflict() {
        let mut d = dram();
        let mut rng = crate::util::rng::Xoshiro256::new(3);
        for _ in 0..8192 {
            d.access(rng.gen_range(1 << 32), false);
        }
        let s = &d.stats;
        assert!(
            s.row_conflicts > s.row_hits,
            "conflicts={} hits={}",
            s.row_conflicts,
            s.row_hits
        );
    }

    #[test]
    fn vault_balance_sequential_is_even() {
        let mut d = dram();
        for i in 0..32 * 1024u64 {
            d.access(i * 64, false);
        }
        assert!((d.vault_imbalance() - 1.0).abs() < 0.01);
    }

    #[test]
    fn md1_grows_superlinearly() {
        let w1 = md1_wait(100.0, 0.5);
        let w2 = md1_wait(100.0, 0.9);
        assert!(w1 > 0.0);
        assert!(w2 > 5.0 * w1);
        assert_eq!(md1_wait(100.0, 0.0), 0.0);
        assert!(md1_wait(100.0, 2.0).is_finite()); // clamped
    }
}
