//! Replay engine + timing model.
//!
//! Two phases (DESIGN.md §3):
//!
//! 1. **Replay** (exact): every access of every core's trace walks the
//!    configured hierarchy (private L1/L2, shared L3, HMC DRAM with row
//!    buffers, optional stream prefetcher), interleaved round-robin in
//!    64-access quanta. This yields exact hit/miss/writeback/row-outcome
//!    counts, per-service-level load counts split by dependence, NUCA hop
//!    sums and energy events.
//! 2. **Timing** (closed-form fixed point): per-core cycles are computed
//!    from the aggregates with an MLP-limited interval model (OoO can
//!    overlap independent misses up to min(MSHRs, ROB-window density);
//!    in-order barely overlaps), then DRAM queuing (M/D/1 at the
//!    controller/link) and the bandwidth roofline are applied and the
//!    loop iterates until the DRAM latency stops moving.
//!
//! The model trades absolute cycle accuracy for speed and transparency;
//! the paper's *relative* claims (who wins, where crossovers happen) are
//! driven by hit ratios, bandwidth ceilings and queuing — all first-class
//! here.

use super::cache::{Cache, LookupResult};
use super::config::{CoreModel, SystemConfig};
use super::dram::{md1_wait, Dram};
use super::energy::{energy, EnergyBreakdown, EnergyEvents};
use super::events::SoaTrace;
use super::noc::{HopHistogram, Mesh};
use super::prefetcher::StreamPrefetcher;
use super::{Access, Trace};
use crate::util::cancel;
use crate::util::json::Json;
use crate::util::telemetry::{self, metrics};

/// Service level of a load.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Level {
    L1 = 0,
    L2 = 1,
    L3 = 2,
    Dram = 3,
}

/// Per-core replay aggregates.
#[derive(Debug, Default, Clone)]
struct CoreAgg {
    instr: u64,
    ops: u64,
    loads: u64,
    stores: u64,
    line_touches: u64,
    /// Load counts by [dep][level].
    cnt: [[u64; 4]; 2],
    /// Demand (load+store) miss counters — exclude writeback and prefetch
    /// traffic so LFMR/MPKI match the paper's definitions. `d_llc_miss`
    /// counts demand misses at the deepest declared cache level.
    d_l1_miss: u64,
    d_llc_miss: u64,
    /// Demand loads that hit a prefetched L2 line, by original source
    /// (L3 / DRAM). Charged a late-prefetch partial latency: a degree-2
    /// stream prefetcher cannot fully hide the fetch at high demand rates.
    pf_hit_l3: u64,
    pf_hit_dram: u64,
    /// Sum of unloaded DRAM service cycles over this core's DRAM loads.
    dram_service_sum: f64,
    /// NUCA: total mesh hops for L3 + memory-controller trips.
    noc_hops: u64,
    noc_requests: u64,
}

/// Everything the methodology needs from one simulation run.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Label of the system spec this run was lowered from.
    pub system: String,
    pub core_model: CoreModel,
    pub cores: usize,
    /// Wall-clock seconds (slowest core).
    pub time_s: f64,
    /// Total cycles of the slowest core.
    pub cycles: f64,
    pub instr: u64,
    pub ipc: f64,
    /// Fraction of cycles lost to data-access stalls (top-down
    /// "Memory Bound" — Step 1's filter metric).
    pub memory_bound: f64,
    // Cache counters (aggregate over cores).
    pub l1_hits: u64,
    pub l1_misses: u64,
    pub l2_hits: u64,
    pub l2_misses: u64,
    pub l3_hits: u64,
    pub l3_misses: u64,
    // Derived metrics (paper §2.4.1).
    pub mpki: f64,
    pub lfmr: f64,
    pub ai: f64,
    /// Mean loaded latency per load, cycles (Figs 8/13) with per-level
    /// contributions [l1, l2, l3, dram].
    pub amat: f64,
    pub amat_parts: [f64; 4],
    /// Fraction of loads serviced at each level (Fig 11).
    pub level_fracs: [f64; 4],
    // DRAM.
    pub dram_reads: u64,
    pub dram_writes: u64,
    pub row_hit_rate: f64,
    /// Achieved DRAM bandwidth, bytes/sec.
    pub bw_bytes_s: f64,
    /// Channel/link utilization after the fixed point (0..~1).
    pub dram_rho: f64,
    /// Loaded DRAM latency seen by a demand load (cycles).
    pub dram_loaded_lat: f64,
    /// Max/mean vault pressure (case study 1 load balance).
    pub vault_imbalance: f64,
    // Prefetcher.
    pub pf_issued: u64,
    pub pf_accuracy: f64,
    // NoC (NUCA or NDP-mesh runs).
    pub noc_mean_hops: f64,
    pub hop_hist: Vec<u64>,
    /// LLC (or DRAM for NDP) misses attributed to each static basic block
    /// (Fig 24), indexed by `Access::bb`.
    pub bb_llc_misses: Vec<u64>,
    // Energy.
    pub energy: EnergyBreakdown,
}

impl SimResult {
    /// Performance = 1 / execution time (paper footnote 11).
    pub fn perf(&self) -> f64 {
        1.0 / self.time_s
    }
}

/// Options beyond the system config: the NDP-mesh model of case study 1.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimOptions {
    /// Model the inter-vault NoC for NDP (case study 1 / §5.1): each
    /// memory access pays mesh hops between the core's vault and the
    /// target vault. Off for the paper's main configuration.
    pub ndp_mesh: bool,
}

pub fn simulate(cfg: &SystemConfig, trace: &Trace) -> SimResult {
    simulate_opt(cfg, trace, SimOptions::default())
}

/// Array-of-structs entry point: transposes the trace into the SoA
/// replay buffer and runs the fast path. One-shot callers (unit tests,
/// `damov sim`) land here; the sweep builds the buffer once per
/// (function, cores) via [`super::TraceAnalysis`] and calls
/// [`simulate_events`] directly so the transposition is not repeated
/// per config point.
pub fn simulate_opt(cfg: &SystemConfig, trace: &Trace, opt: SimOptions) -> SimResult {
    simulate_events_opt(cfg, &SoaTrace::from_trace(trace), opt)
}

/// Replay a pre-transposed [`SoaTrace`] (see [`simulate_opt`]).
pub fn simulate_events(cfg: &SystemConfig, events: &SoaTrace) -> SimResult {
    simulate_events_opt(cfg, events, SimOptions::default())
}

pub fn simulate_events_opt(cfg: &SystemConfig, events: &SoaTrace, opt: SimOptions) -> SimResult {
    assert_eq!(
        events.cores(),
        cfg.cores,
        "trace has {} threads but config has {} cores",
        events.cores(),
        cfg.cores
    );
    let n = cfg.cores;
    let line = cfg.l1.line_bytes as u64;
    let total_accesses: usize = events.total_accesses();
    let _sim_span = telemetry::span_args(
        "simulate",
        vec![
            ("system".to_string(), Json::from(cfg.label.clone())),
            ("cores".to_string(), Json::from(n)),
            ("accesses".to_string(), Json::from(total_accesses)),
        ],
    );
    metrics::counter("sim.runs").incr();
    metrics::counter("sim.accesses").add(total_accesses as u64);

    // --- Phase 1: replay ---
    let replay_t0 = std::time::Instant::now();
    let replay_span = telemetry::span("replay");
    let mut l1s: Vec<Cache> = (0..n).map(|_| Cache::new(&cfg.l1)).collect();
    let mut l2s: Vec<Option<Cache>> = (0..n).map(|_| cfg.l2.as_ref().map(Cache::new)).collect();
    let mut l3 = cfg.l3.as_ref().map(Cache::new);
    let mut dram = Dram::new(&cfg.dram);
    let mut pfs: Vec<Option<StreamPrefetcher>> = (0..n)
        .map(|_| {
            if cfg.prefetch {
                Some(StreamPrefetcher::new(cfg.pf_streams, cfg.pf_degree))
            } else {
                None
            }
        })
        .collect();

    let mut agg: Vec<CoreAgg> = vec![CoreAgg::default(); n];
    // Lines currently in L2 that arrived via prefetch and have not yet
    // been demanded: line -> came_from_dram.
    let mut pf_pending: Vec<std::collections::HashMap<u64, bool>> =
        (0..n).map(|_| std::collections::HashMap::new()).collect();
    let mut ev = EnergyEvents::default();
    let mut last_line: Vec<u64> = vec![u64::MAX; n];
    let mut hop_hist = HopHistogram::default();
    let mut bb_llc = vec![0u64; 256];

    // NUCA mesh: cores at nodes 0..cores, L3 banks spread over the mesh by
    // line interleave; memory controllers on the extra row.
    let nuca_mesh = Mesh::new(cfg.mesh_side(), cfg.mesh_side());
    // NDP mesh (case study 1): vault grid.
    let ndp_mesh = Mesh::square_for(cfg.dram.vaults);

    let quantum = 64usize;
    // Cooperative cancellation: observe the thread's cancel token every
    // ~64K replayed accesses so a watchdog soft-cancel (job timeout,
    // sweep deadline) unwinds a long replay with bounded latency. The
    // check amortizes to one counter add per quantum.
    const CANCEL_POLL_EVERY: usize = 64 * 1024;
    let mut since_poll = 0usize;
    let mut cursors = vec![0usize; n];
    let mut live = n;
    while live > 0 {
        live = 0;
        for core in 0..n {
            let t = &events.per_core[core];
            let mut i = cursors[core];
            if i >= t.len() {
                continue;
            }
            let end = (i + quantum).min(t.len());
            since_poll += end - i;
            if since_poll >= CANCEL_POLL_EVERY {
                since_poll = 0;
                cancel::poll();
            }
            // SoA hot loop: each quantum reads the five columns as dense
            // sequential streams (CoreEvents::get is inlined).
            while i < end {
                let a = t.get(i);
                i += 1;
                replay_one(
                    cfg,
                    opt,
                    core,
                    a,
                    &mut l1s,
                    &mut l2s,
                    &mut l3,
                    &mut dram,
                    &mut pfs,
                    &mut pf_pending,
                    &mut agg,
                    &mut ev,
                    &mut last_line,
                    &mut hop_hist,
                    &mut bb_llc,
                    &nuca_mesh,
                    &ndp_mesh,
                    line,
                );
            }
            cursors[core] = i;
            if i < t.len() {
                live += 1;
            }
        }
    }

    drop(replay_span);
    {
        let secs = replay_t0.elapsed().as_secs_f64();
        if secs > 0.0 {
            metrics::histogram("sim.replay_acc_per_s")
                .record((total_accesses as f64 / secs) as u64);
        }
    }

    // Aggregate cache counters.
    let l1_hits: u64 = l1s.iter().map(|c| c.hits).sum();
    let l1_misses: u64 = l1s.iter().map(|c| c.misses).sum();
    let l2_hits: u64 = l2s.iter().flatten().map(|c| c.hits).sum();
    let l2_misses: u64 = l2s.iter().flatten().map(|c| c.misses).sum();
    let (l3_hits, l3_misses) = l3.as_ref().map(|c| (c.hits, c.misses)).unwrap_or((0, 0));
    metrics::counter("sim.l1_hits").add(l1_hits);
    metrics::counter("sim.l1_misses").add(l1_misses);
    metrics::counter("sim.l2_hits").add(l2_hits);
    metrics::counter("sim.l2_misses").add(l2_misses);
    metrics::counter("sim.l3_hits").add(l3_hits);
    metrics::counter("sim.l3_misses").add(l3_misses);
    metrics::counter("sim.dram_reads").add(dram.stats.reads);
    metrics::counter("sim.dram_writes").add(dram.stats.writes);

    // --- Phase 2: timing fixed point ---
    let timing_span = telemetry::span("timing");
    let instr: u64 = agg.iter().map(|a| a.instr).sum();
    let total_loads: u64 = agg.iter().map(|a| a.loads).sum();
    let width = cfg.issue_width as f64;

    // Unloaded per-level latencies (cycles).
    let lat_l1 = cfg.l1.latency_cycles as f64;
    let lat_l2 = lat_l1 + cfg.l2.map(|c| c.latency_cycles).unwrap_or(0) as f64;
    let lat_l3_base = lat_l2 + cfg.l3.map(|c| c.latency_cycles).unwrap_or(0) as f64;

    // Mean NUCA hop latency per L3 request.
    let total_noc_reqs: u64 = agg.iter().map(|a| a.noc_requests).sum();
    let mean_hops = if total_noc_reqs > 0 {
        agg.iter().map(|a| a.noc_hops).sum::<u64>() as f64 / total_noc_reqs as f64
    } else if opt.ndp_mesh {
        hop_hist.mean()
    } else {
        0.0
    };

    // DRAM traffic (bytes) that crosses the bottleneck resource.
    let dram_bytes = ev.dram_bytes as f64;
    let mean_service = dram.mean_service_cycles();
    let vault_imbalance = dram.vault_imbalance();
    // Imbalanced vault pressure lowers the usable aggregate bandwidth.
    let peak_bw = cfg.peak_bw() / vault_imbalance.max(1.0).min(4.0);

    let mut dram_extra = if cfg.is_direct_vault() {
        0.0
    } else {
        cfg.dram.host_link_cycles as f64
    };
    if opt.ndp_mesh {
        dram_extra += mean_hops * cfg.noc.cycles_per_hop as f64;
    }

    // Loaded-latency fixed point. Two regimes, modeled separately so the
    // iteration is stable (see DESIGN.md §3):
    //  * latency regime (rho well below 1): M/D/1 queuing inflates the
    //    DRAM latency seen by stalls; the feedback rho used for *timing*
    //    is capped at 0.75 — past that point real cores throttle at the
    //    MSHRs and the system self-regulates at the bandwidth limit;
    //  * bandwidth regime: execution time has a hard floor of
    //    bytes / peak_bw. The *reported* rho/loaded latency use the true
    //    utilization so AMAT reflects saturation.
    // Lookup latency down the declared hierarchy before memory is
    // reached (collapses to lat_l1 when no L2/L3 exists).
    let base_dram = lat_l3_base;
    let mut dram_lat = base_dram + mean_service + dram_extra;
    let mut noc_queue = 0.0;
    let mut time_cycles = 0.0f64;
    let mut rho = 0.0;
    let bw_floor_cycles = dram_bytes / peak_bw * cfg.freq_hz;

    let stall_cycles = |dram_lat: f64, noc_queue: f64| -> f64 {
        let lat_l3 = lat_l3_base
            + if cfg.is_nuca() {
                mean_hops * cfg.noc.cycles_per_hop as f64 + noc_queue
            } else {
                0.0
            };
        let mut max_cycles = 0.0f64;
        for a in agg.iter() {
            let base = a.instr as f64 / width;
            let lvl_lat = [lat_l1, lat_l2, lat_l3, dram_lat];
            // MLP is a property of the core's *combined* outstanding-miss
            // stream: misses at different levels overlap with each other,
            // so the ROB-window density uses all beyond-L1 loads.
            let miss_loads: u64 = (1..4).map(|l| a.cnt[0][l] + a.cnt[1][l]).sum::<u64>()
                + a.pf_hit_l3
                + a.pf_hit_dram;
            let inter = (a.instr as f64 / (miss_loads.max(1)) as f64).max(1.0);
            let window_mlp = (cfg.rob as f64 / inter).max(1.0);
            let cap = match cfg.core {
                CoreModel::OutOfOrder => cfg.mshrs as f64,
                CoreModel::InOrder => 2.0,
            };
            let mlp = window_mlp.min(cap).max(1.0);
            let mut stall = 0.0;
            for (lvl, &lat) in lvl_lat.iter().enumerate() {
                let dep = a.cnt[1][lvl] as f64;
                let indep = a.cnt[0][lvl] as f64;
                // Dependent loads serialize fully.
                stall += dep * lat;
                if indep > 0.0 && lvl > 0 {
                    stall += indep * lat / mlp;
                }
                // Independent L1 hits are pipelined (no stall).
            }
            // Late-prefetch partial latency: a degree-2 stream prefetcher
            // hides about half of the source latency at steady demand.
            const LATE: f64 = 0.5;
            stall += a.pf_hit_l3 as f64 * (lat_l2 + LATE * (lat_l3 - lat_l2)) / mlp;
            stall += a.pf_hit_dram as f64 * (lat_l2 + LATE * (dram_lat - lat_l2)) / mlp;
            max_cycles = max_cycles.max(base + stall);
        }
        max_cycles
    };

    let mut fp_iters = 0u64;
    for _ in 0..12 {
        cancel::poll();
        fp_iters += 1;
        let new_time = stall_cycles(dram_lat, noc_queue).max(bw_floor_cycles);
        rho = (dram_bytes / (new_time / cfg.freq_hz)) / peak_bw;
        let rho_fb = rho.min(0.75); // timing feedback cap (self-regulation)
        let queue = md1_wait(mean_service, rho_fb);
        let new_dram_lat = base_dram + mean_service + dram_extra + queue;
        // NUCA NoC contention from L3 traffic.
        if cfg.is_nuca() {
            let links = (2 * nuca_mesh.nodes()) as f64;
            let inj = total_noc_reqs as f64 / new_time.max(1.0);
            let load = super::noc::NocLoad {
                inj_rate: inj,
                mean_hops: mean_hops.max(1.0),
                service: cfg.noc.cycles_per_hop as f64,
            };
            noc_queue = load.queue_cycles(links);
        }
        let moved = (new_dram_lat - dram_lat).abs() / dram_lat.max(1.0);
        // Damped update for stability.
        dram_lat = 0.5 * dram_lat + 0.5 * new_dram_lat;
        time_cycles = new_time;
        if moved < 1e-3 {
            break;
        }
    }
    // Reported loaded latency reflects true utilization (saturated queues).
    dram_lat = base_dram + mean_service + dram_extra + md1_wait(mean_service, rho);
    metrics::histogram("sim.fixedpoint_iters").record(fp_iters);
    drop(timing_span);

    if telemetry::log::enabled(telemetry::Level::Debug) {
        for (i, a) in agg.iter().enumerate().take(2) {
            let detail = format!(
                "instr={} loads={} cnt_indep={:?} cnt_dep={:?} pf=({},{}) \
                 lat=[{lat_l1},{lat_l2},{lat_l3_base},{dram_lat:.0}] svc={mean_service:.0} time={time_cycles:.0} \
                 stall_at_dlat={:.0} floor={bw_floor_cycles:.0}",
                a.instr,
                a.loads,
                a.cnt[0],
                a.cnt[1],
                a.pf_hit_l3,
                a.pf_hit_dram,
                stall_cycles(dram_lat, noc_queue),
            );
            telemetry::debug(
                "sim-core",
                &[("core", Json::from(i)), ("detail", Json::from(detail))],
            );
        }
    }

    // Memory-bound % from the final latency set (recompute stalls of the
    // slowest core; use aggregate ratio which is what VTune reports).
    let lat_l3 = lat_l3_base
        + if cfg.is_nuca() {
            mean_hops * cfg.noc.cycles_per_hop as f64 + noc_queue
        } else {
            0.0
        };
    let lvl_lat = [lat_l1, lat_l2, lat_l3, dram_lat];
    let mut total_stall = 0.0;
    let mut total_base = 0.0;
    for a in agg.iter() {
        total_base += a.instr as f64 / width;
        let miss_loads: u64 = (1..4).map(|l| a.cnt[0][l] + a.cnt[1][l]).sum::<u64>()
            + a.pf_hit_l3
            + a.pf_hit_dram;
        let inter = (a.instr as f64 / (miss_loads.max(1)) as f64).max(1.0);
        let cap = match cfg.core {
            CoreModel::OutOfOrder => cfg.mshrs as f64,
            CoreModel::InOrder => 2.0,
        };
        let mlp = (cfg.rob as f64 / inter).max(1.0).min(cap).max(1.0);
        for (lvl, &lat) in lvl_lat.iter().enumerate() {
            let dep = a.cnt[1][lvl] as f64;
            let indep = a.cnt[0][lvl] as f64;
            total_stall += dep * lat;
            if indep > 0.0 && lvl > 0 {
                total_stall += indep * lat / mlp;
            }
        }
        total_stall += a.pf_hit_l3 as f64 * (lat_l2 + 0.5 * (lat_l3 - lat_l2)) / mlp;
        total_stall += a.pf_hit_dram as f64 * (lat_l2 + 0.5 * (dram_lat - lat_l2)) / mlp;
    }
    let memory_bound = total_stall / (total_base + total_stall).max(1.0);

    // AMAT (loaded) + per-level parts, over loads.
    let mut amat_parts = [0.0f64; 4];
    let mut level_counts = [0u64; 4];
    for a in agg.iter() {
        for lvl in 0..4 {
            level_counts[lvl] += a.cnt[0][lvl] + a.cnt[1][lvl];
        }
        // Prefetch-covered loads are serviced at L2.
        level_counts[1] += a.pf_hit_l3 + a.pf_hit_dram;
    }
    for lvl in 0..4 {
        amat_parts[lvl] = lvl_lat[lvl] * level_counts[lvl] as f64 / total_loads.max(1) as f64;
    }
    let amat: f64 = amat_parts.iter().sum();
    let level_fracs = [
        level_counts[0] as f64 / total_loads.max(1) as f64,
        level_counts[1] as f64 / total_loads.max(1) as f64,
        level_counts[2] as f64 / total_loads.max(1) as f64,
        level_counts[3] as f64 / total_loads.max(1) as f64,
    ];

    let time_s = time_cycles / cfg.freq_hz;
    let ops: u64 = agg.iter().map(|a| a.ops).sum();
    let line_touches: u64 = agg.iter().map(|a| a.line_touches).sum();

    // LFMR / MPKI over *demand* accesses (paper §2.4.1; writeback and
    // prefetch traffic excluded). For single-level hierarchies (NDP) we
    // report the L1-based equivalents so the fields stay meaningful.
    let d_l1_miss: u64 = agg.iter().map(|a| a.d_l1_miss).sum();
    let d_llc_miss: u64 = agg.iter().map(|a| a.d_llc_miss).sum();
    let (lfmr, mpki) = if cfg.l2.is_some() || cfg.l3.is_some() {
        (
            d_llc_miss as f64 / d_l1_miss.max(1) as f64,
            d_llc_miss as f64 / (instr as f64 / 1000.0),
        )
    } else {
        (1.0, d_l1_miss as f64 / (instr as f64 / 1000.0))
    };

    let dram_total = dram.stats.reads + dram.stats.writes;
    let row_hit_rate = dram.stats.row_hits as f64 / dram_total.max(1) as f64;

    let pf_issued: u64 = pfs.iter().flatten().map(|p| p.issued).sum();
    let pf_acc = {
        let (u, i): (u64, u64) = pfs
            .iter()
            .flatten()
            .fold((0, 0), |(u, i), p| (u + p.useful, i + p.issued));
        if i == 0 {
            1.0
        } else {
            (u as f64 / i as f64).min(1.0)
        }
    };

    let e = energy(cfg, &ev);

    SimResult {
        system: cfg.label.clone(),
        core_model: cfg.core,
        cores: n,
        time_s,
        cycles: time_cycles,
        instr,
        ipc: instr as f64 / time_cycles.max(1.0),
        memory_bound,
        l1_hits,
        l1_misses,
        l2_hits,
        l2_misses,
        l3_hits,
        l3_misses,
        mpki,
        lfmr,
        ai: ops as f64 / line_touches.max(1) as f64,
        amat,
        amat_parts,
        level_fracs,
        dram_reads: dram.stats.reads,
        dram_writes: dram.stats.writes,
        row_hit_rate,
        bw_bytes_s: dram_bytes / time_s.max(1e-12),
        dram_rho: rho,
        dram_loaded_lat: dram_lat,
        vault_imbalance,
        pf_issued,
        pf_accuracy: pf_acc,
        noc_mean_hops: mean_hops,
        hop_hist: hop_hist.counts.clone(),
        bb_llc_misses: bb_llc,
        energy: e,
    }
}

/// Replay a single access through the hierarchy, updating all state.
#[allow(clippy::too_many_arguments)]
#[inline]
fn replay_one(
    cfg: &SystemConfig,
    opt: SimOptions,
    core: usize,
    a: Access,
    l1s: &mut [Cache],
    l2s: &mut [Option<Cache>],
    l3: &mut Option<Cache>,
    dram: &mut Dram,
    pfs: &mut [Option<StreamPrefetcher>],
    pf_pending: &mut [std::collections::HashMap<u64, bool>],
    agg: &mut [CoreAgg],
    ev: &mut EnergyEvents,
    last_line: &mut [u64],
    hop_hist: &mut HopHistogram,
    bb_llc: &mut [u64],
    nuca_mesh: &Mesh,
    ndp_mesh: &Mesh,
    line: u64,
) {
    let ag = &mut agg[core];
    ag.instr += a.gap as u64 + 1;
    ag.ops += a.ops as u64;
    if a.write {
        ag.stores += 1;
    } else {
        ag.loads += 1;
    }
    let ln = a.addr / line;
    if ln != last_line[core] {
        ag.line_touches += 1;
        last_line[core] = ln;
    }
    let dep = a.dep as usize;

    // Read-only L1 (NDP logic-layer cores): stores bypass the cache
    // entirely and write through to memory.
    if cfg.l1_read_only && a.write {
        l1s[core].invalidate(a.addr);
        let (_, _svc) = dram.access(a.addr, true);
        // Fine-grained 8 B write through the logic layer (no
        // read-for-ownership, no full-line transfer).
        ev.dram_bytes += 8;
        ev.logic_bytes += 8;
        if !cfg.is_direct_vault() {
            ev.link_bytes += 8;
        }
        if opt.ndp_mesh {
            let from = core % cfg.dram.vaults;
            let hops = ndp_mesh.hops(from, dram.vault_of(a.addr));
            hop_hist.record(hops);
            ev.noc_router += hops + 1;
            ev.noc_links += hops;
        }
        return;
    }

    // L1.
    match l1s[core].access(a.addr, a.write && !cfg.l1_read_only) {
        LookupResult::Hit => {
            ev.l1_hits += 1;
            if !a.write {
                agg[core].cnt[dep][0] += 1;
            }
            return;
        }
        LookupResult::Miss { evicted } => {
            ev.l1_misses += 1;
            agg[core].d_l1_miss += 1;
            if let Some(e) = evicted {
                if e.dirty {
                    // Writeback to the next level down: L2 if declared,
                    // else the LLC, else memory. (A read-only L1 never
                    // holds dirty lines.)
                    if let Some(l2) = l2s[core].as_mut() {
                        let _ = l2.access(e.line_addr, true);
                        ev.l2_hits += 1; // writeback port access energy
                    } else if let Some(l3c) = l3.as_mut() {
                        let _ = l3c.access(e.line_addr, true);
                        ev.l3_hits += 1;
                    } else if !cfg.l1_read_only {
                        dram.access(e.line_addr, true);
                        ev.dram_bytes += line;
                        ev.logic_bytes += line;
                        if !cfg.is_direct_vault() {
                            ev.link_bytes += line;
                        }
                    }
                }
            }
        }
    }

    if cfg.l2.is_none() && cfg.l3.is_none() {
        // Single-level hierarchy: L1 miss -> memory directly (NDP: the
        // vault under the logic layer).
        let (_, svc) = dram.access(a.addr, a.write);
        bb_llc[a.bb as usize] += 1;
        ev.dram_bytes += line;
        ev.logic_bytes += line;
        if !cfg.is_direct_vault() {
            ev.link_bytes += line;
        }
        if opt.ndp_mesh {
            let from = core % cfg.dram.vaults;
            let hops = ndp_mesh.hops(from, dram.vault_of(a.addr));
            hop_hist.record(hops);
            ev.noc_router += hops + 1;
            ev.noc_links += hops;
        }
        if !a.write {
            agg[core].cnt[dep][3] += 1;
            agg[core].dram_service_sum += svc as f64;
        }
        return;
    }

    // Private L2, when declared.
    let l2_line = a.addr / line;
    let mut l2_result_hit = false;
    let mut pf_src: Option<bool> = None; // Some(from_dram) if pf-covered
    if let Some(l2) = l2s[core].as_mut() {
        match l2.access(a.addr, a.write) {
            LookupResult::Hit => {
                ev.l2_hits += 1;
                l2_result_hit = true;
                pf_src = pf_pending[core].remove(&l2_line);
            }
            LookupResult::Miss { evicted } => {
                ev.l2_misses += 1;
                if let Some(e) = evicted {
                    if e.dirty {
                        if let Some(l3c) = l3.as_mut() {
                            let _ = l3c.access(e.line_addr, true);
                            ev.l3_hits += 1; // writeback access energy
                        } else {
                            dram.access(e.line_addr, true);
                            ev.dram_bytes += line;
                            ev.logic_bytes += line;
                            if !cfg.is_direct_vault() {
                                ev.link_bytes += line;
                            }
                        }
                    }
                }
            }
        }
    }

    // Prefetcher observes the L2 access stream (demand L1 misses).
    if let Some(pf) = pfs[core].as_mut() {
        let pf_lines = pf.observe(l2_line);
        for pl in pf_lines {
            let pf_addr = pl * line;
            // Fill L2 (and L3) with the prefetched line; count DRAM
            // traffic if the line was not on chip.
            let in_l2 = l2s[core].as_ref().unwrap().contains(pf_addr);
            let on_chip = in_l2 || l3.as_ref().map(|c| c.contains(pf_addr)).unwrap_or(false);
            if !in_l2 {
                // Only a line actually moved into L2 counts as covered.
                pf_pending[core].insert(pl, !on_chip);
                if pf_pending[core].len() > 8192 {
                    pf_pending[core].clear(); // stale-entry pressure valve
                }
            }
            if !on_chip {
                let (_, _svc) = dram.access(pf_addr, false);
                ev.dram_bytes += line;
                ev.logic_bytes += line;
                if !cfg.is_direct_vault() {
                    ev.link_bytes += line;
                }
                if let Some(l3c) = l3.as_mut() {
                    if let Some(evd) = l3c.fill(pf_addr) {
                        if evd.dirty {
                            dram.access(evd.line_addr, true);
                            ev.dram_bytes += line;
                            ev.logic_bytes += line;
                            if !cfg.is_direct_vault() {
                                ev.link_bytes += line;
                            }
                        }
                    }
                }
            }
            if let Some(evd) = l2s[core].as_mut().unwrap().fill(pf_addr) {
                if evd.dirty {
                    if let Some(l3c) = l3.as_mut() {
                        let _ = l3c.access(evd.line_addr, true);
                        ev.l3_hits += 1;
                    }
                }
            }
        }
    }

    if l2_result_hit {
        if !a.write {
            match pf_src {
                Some(true) => agg[core].pf_hit_dram += 1,
                Some(false) => agg[core].pf_hit_l3 += 1,
                None => agg[core].cnt[dep][1] += 1,
            }
        }
        return;
    }

    // Shared LLC, when declared; otherwise the miss goes to memory.
    let Some(l3c) = l3.as_mut() else {
        let (_, svc) = dram.access(a.addr, a.write);
        bb_llc[a.bb as usize] += 1;
        agg[core].d_llc_miss += 1;
        ev.dram_bytes += line;
        ev.logic_bytes += line;
        if !cfg.is_direct_vault() {
            ev.link_bytes += line;
        }
        if !a.write {
            agg[core].cnt[dep][3] += 1;
            agg[core].dram_service_sum += svc as f64;
        }
        return;
    };
    // NUCA: request travels core -> L3 bank of this line.
    if cfg.is_nuca() {
        let bank = (l2_line as usize) % cfg.l3_banks;
        let bank_node = bank % nuca_mesh.nodes();
        let core_node = core % nuca_mesh.nodes();
        let hops = nuca_mesh.hops(core_node, bank_node);
        agg[core].noc_hops += hops;
        agg[core].noc_requests += 1;
        ev.noc_router += hops + 1;
        ev.noc_links += hops;
    }
    match l3c.access(a.addr, a.write) {
        LookupResult::Hit => {
            ev.l3_hits += 1;
            if !a.write {
                agg[core].cnt[dep][2] += 1;
            }
        }
        LookupResult::Miss { evicted } => {
            ev.l3_misses += 1;
            agg[core].d_llc_miss += 1;
            bb_llc[a.bb as usize] += 1;
            if let Some(e) = evicted {
                if e.dirty {
                    dram.access(e.line_addr, true);
                    ev.dram_bytes += line;
                    ev.logic_bytes += line;
                    if !cfg.is_direct_vault() {
                        ev.link_bytes += line;
                    }
                }
            }
            let (_, svc) = dram.access(a.addr, a.write);
            ev.dram_bytes += line;
            ev.logic_bytes += line;
            if !cfg.is_direct_vault() {
                ev.link_bytes += line;
            }
            if !a.write {
                agg[core].cnt[dep][3] += 1;
                agg[core].dram_service_sum += svc as f64;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::{CoreModel, SystemConfig};
    use crate::sim::Access;
    use crate::util::rng::Xoshiro256;

    /// Sequential streaming trace: `n` loads walking a large array.
    fn stream_trace(cores: usize, n_per_core: usize, stride: u64) -> Vec<Vec<Access>> {
        (0..cores)
            .map(|c| {
                let base = c as u64 * (1 << 30);
                (0..n_per_core)
                    .map(|i| Access::load(base + i as u64 * stride, 2, 2))
                    .collect()
            })
            .collect()
    }

    /// Pointer-chasing trace over a working set of `lines` lines.
    fn chase_trace(cores: usize, n_per_core: usize, lines: u64) -> Vec<Vec<Access>> {
        (0..cores)
            .map(|c| {
                let mut rng = Xoshiro256::new(c as u64 + 99);
                let base = c as u64 * (1 << 30);
                (0..n_per_core)
                    .map(|_| Access::load_dep(base + rng.gen_range(lines) * 64, 4, 1))
                    .collect()
            })
            .collect()
    }

    /// Small hot working set that fits in L1.
    fn hot_trace(cores: usize, n_per_core: usize) -> Vec<Vec<Access>> {
        (0..cores)
            .map(|c| {
                let base = c as u64 * (1 << 30);
                (0..n_per_core)
                    .map(|i| Access::load(base + (i as u64 % 128) * 64, 3, 8))
                    .collect()
            })
            .collect()
    }

    #[test]
    fn stream_misses_dominate_l1() {
        let cfg = SystemConfig::host(1, CoreModel::OutOfOrder);
        let r = simulate(&cfg, &stream_trace(1, 20_000, 64));
        // Every access touches a fresh line.
        assert!(r.l1_misses > 19_000, "l1_misses={}", r.l1_misses);
        assert!(r.lfmr > 0.9, "lfmr={}", r.lfmr);
        assert!(r.mpki > 10.0, "mpki={}", r.mpki);
    }

    #[test]
    fn hot_set_hits_l1() {
        let cfg = SystemConfig::host(1, CoreModel::OutOfOrder);
        let r = simulate(&cfg, &hot_trace(1, 100_000));
        assert!(r.l1_hits > 99_000);
        assert!(r.mpki < 1.0, "mpki={}", r.mpki);
        assert!(r.memory_bound < 0.3, "memory_bound={}", r.memory_bound);
    }

    #[test]
    fn ndp_beats_host_on_bandwidth_bound_many_cores() {
        // Class-1a shape: at 64 cores a streaming workload saturates the
        // host link but not the NDP internal bandwidth.
        let n = 64;
        let t = stream_trace(n, 8_000, 64);
        let host = simulate(&SystemConfig::host(n, CoreModel::OutOfOrder), &t);
        let ndp = simulate(&SystemConfig::ndp(n, CoreModel::OutOfOrder), &t);
        assert!(
            ndp.perf() > 1.3 * host.perf(),
            "ndp={} host={}",
            ndp.perf(),
            host.perf()
        );
        assert!(host.dram_rho > 0.8, "host rho={}", host.dram_rho);
    }

    #[test]
    fn host_beats_ndp_on_cache_friendly() {
        // Class-2c shape: L2-resident working set loves the deep hierarchy.
        let cores = 4;
        let t: Vec<Vec<Access>> = (0..cores)
            .map(|c| {
                let base = c as u64 * (1 << 30);
                // 128 KiB per-core working set: fits L2, not L1.
                (0..40_000)
                    .map(|i| Access::load(base + (i as u64 * 37 % 2048) * 64, 6, 24))
                    .collect()
            })
            .collect();
        let host = simulate(&SystemConfig::host(cores, CoreModel::OutOfOrder), &t);
        let ndp = simulate(&SystemConfig::ndp(cores, CoreModel::OutOfOrder), &t);
        assert!(
            host.perf() > ndp.perf(),
            "host={} ndp={}",
            host.perf(),
            ndp.perf()
        );
    }

    #[test]
    fn dependent_chase_is_latency_bound_and_ndp_helps() {
        // Class-1b shape: low MPKI (low rate), high LFMR, dependent loads.
        let cores = 4;
        let t = chase_trace(cores, 8_000, 1 << 22); // 256 MiB working set
        let host = simulate(&SystemConfig::host(cores, CoreModel::OutOfOrder), &t);
        let ndp = simulate(&SystemConfig::ndp(cores, CoreModel::OutOfOrder), &t);
        assert!(host.lfmr > 0.9, "lfmr={}", host.lfmr);
        assert!(ndp.perf() > host.perf());
        // Dominated by latency, not bandwidth.
        assert!(host.dram_rho < 0.5, "rho={}", host.dram_rho);
        assert!(host.memory_bound > 0.5);
    }

    #[test]
    fn prefetcher_helps_streaming_at_low_core_count() {
        let cfg = SystemConfig::host(1, CoreModel::InOrder);
        let cfg_pf = SystemConfig::host_prefetch(1, CoreModel::InOrder);
        let t = stream_trace(1, 20_000, 64);
        let base = simulate(&cfg, &t);
        let pf = simulate(&cfg_pf, &t);
        assert!(pf.pf_issued > 0);
        assert!(pf.pf_accuracy > 0.5, "acc={}", pf.pf_accuracy);
        // Prefetched lines convert DRAM loads into L2 hits.
        assert!(
            pf.level_fracs[3] < base.level_fracs[3],
            "pf dram frac {} vs {}",
            pf.level_fracs[3],
            base.level_fracs[3]
        );
        assert!(pf.perf() > base.perf());
    }

    #[test]
    fn inorder_slower_than_ooo_on_memory_bound() {
        let t = stream_trace(4, 10_000, 64);
        let ooo = simulate(&SystemConfig::host(4, CoreModel::OutOfOrder), &t);
        let ino = simulate(&SystemConfig::host(4, CoreModel::InOrder), &t);
        assert!(ooo.perf() > ino.perf());
    }

    #[test]
    fn level_fracs_sum_to_one_for_loads() {
        let t = chase_trace(2, 5_000, 1 << 16);
        let r = simulate(&SystemConfig::host(2, CoreModel::OutOfOrder), &t);
        let sum: f64 = r.level_fracs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "sum={sum}");
    }

    #[test]
    fn energy_breakdown_ndp_lacks_l2l3() {
        let t = stream_trace(2, 5_000, 64);
        let ndp = simulate(&SystemConfig::ndp(2, CoreModel::OutOfOrder), &t);
        assert_eq!(ndp.energy.l2, 0.0);
        assert_eq!(ndp.energy.l3, 0.0);
        assert_eq!(ndp.energy.link, 0.0);
        let host = simulate(&SystemConfig::host(2, CoreModel::OutOfOrder), &t);
        assert!(host.energy.l3 > 0.0 && host.energy.link > 0.0);
    }

    #[test]
    fn nuca_reports_hops() {
        let t = stream_trace(4, 5_000, 64);
        let r = simulate(&SystemConfig::host_nuca(4, CoreModel::OutOfOrder), &t);
        assert!(r.noc_mean_hops > 0.0);
        assert!(r.energy.noc > 0.0);
    }

    #[test]
    fn ndp_mesh_option_records_hop_histogram() {
        let t = stream_trace(4, 5_000, 64);
        let r = simulate_opt(
            &SystemConfig::ndp(4, CoreModel::OutOfOrder),
            &t,
            SimOptions { ndp_mesh: true },
        );
        let total: u64 = r.hop_hist.iter().sum();
        assert!(total > 4_000);
        assert!(r.noc_mean_hops > 0.0);
    }

    #[test]
    fn deterministic() {
        let t = chase_trace(2, 3_000, 1 << 16);
        let a = simulate(&SystemConfig::host(2, CoreModel::OutOfOrder), &t);
        let b = simulate(&SystemConfig::host(2, CoreModel::OutOfOrder), &t);
        assert_eq!(a.time_s, b.time_s);
        assert_eq!(a.l3_misses, b.l3_misses);
        assert_eq!(a.energy, b.energy);
    }

    #[test]
    fn shared_soa_buffer_matches_per_call_transposition() {
        // One SoA buffer replayed read-only across several configs (the
        // sweep's memoized-TraceAnalysis pattern) must be byte-identical
        // to transposing per call.
        let t = chase_trace(2, 3_000, 1 << 16);
        let soa = SoaTrace::from_trace(&t);
        for cfg in [
            SystemConfig::host(2, CoreModel::OutOfOrder),
            SystemConfig::host_prefetch(2, CoreModel::InOrder),
            SystemConfig::ndp(2, CoreModel::OutOfOrder),
        ] {
            let a = simulate(&cfg, &t);
            let b = simulate_events(&cfg, &soa);
            assert_eq!(a.time_s, b.time_s);
            assert_eq!(a.l1_hits, b.l1_hits);
            assert_eq!(a.l3_misses, b.l3_misses);
            assert_eq!(a.dram_reads, b.dram_reads);
            assert_eq!(a.energy, b.energy);
        }
    }
}
