//! Dataflow accelerator model for case study 2 (§5.2; substitutes the
//! Aladdin pre-RTL simulator).
//!
//! Aladdin estimates a custom accelerator's runtime from the workload's
//! dynamic data-flow graph: compute latency is the graph's critical path
//! under a resource bound, memory latency comes from the memory system.
//! We model exactly the quantity the case study isolates — the *placement*
//! of the same accelerator: **compute-centric** (off-chip, host-side DRAM
//! latency/bandwidth) vs **NDP** (logic layer: vault latency/bandwidth).
//!
//! The accelerator itself is characterized by three numbers extracted
//! from the kernel's op graph: ops per element, dependent-chain depth per
//! element, and bytes touched per element.

use super::config::SystemConfig;

/// Static description of an accelerated kernel's dataflow.
#[derive(Debug, Clone, Copy)]
pub struct KernelDataflow {
    /// Total arithmetic ops per element of work.
    pub ops_per_elem: f64,
    /// Length of the dependent chain per element (limits pipelining).
    pub chain_depth: f64,
    /// Bytes read+written per element.
    pub bytes_per_elem: f64,
    /// Number of elements.
    pub elems: f64,
    /// Fraction of memory traffic that is latency-bound (dependent /
    /// irregular), as opposed to streamable.
    pub latency_bound_frac: f64,
}

/// Accelerator hardware resources (identical for both placements).
#[derive(Debug, Clone, Copy)]
pub struct AccelConfig {
    /// Functional units (ops/cycle).
    pub fu: f64,
    /// Clock (Hz).
    pub freq_hz: f64,
    /// Outstanding memory requests supported.
    pub mlp: f64,
}

impl Default for AccelConfig {
    fn default() -> Self {
        AccelConfig {
            fu: 16.0,
            freq_hz: 1.0e9,
            mlp: 16.0,
        }
    }
}

/// Placement of the accelerator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    ComputeCentric,
    Ndp,
}

/// Estimated runtime (seconds) of the kernel on the accelerator at the
/// given placement, against the given memory system parameters.
pub fn accel_time(
    k: &KernelDataflow,
    a: &AccelConfig,
    sys: &SystemConfig,
    placement: Placement,
) -> f64 {
    // Compute: resource-bound ops; independent elements pipeline through
    // the datapath, so the dependent chain contributes only pipeline fill.
    let compute_cycles = (k.ops_per_elem * k.elems) / a.fu + k.chain_depth;
    let compute_s = compute_cycles / a.freq_hz;

    // Memory: bandwidth term + latency term for the irregular fraction.
    let bytes = k.bytes_per_elem * k.elems;
    let (bw, lat_cycles) = match placement {
        Placement::ComputeCentric => (
            sys.dram.host_peak_bw,
            (sys.dram.row_hit_cycles + sys.dram.act_cycles / 2 + sys.dram.host_link_cycles) as f64,
        ),
        Placement::Ndp => (
            sys.dram.ndp_peak_bw,
            (sys.dram.row_hit_cycles + sys.dram.act_cycles / 2) as f64,
        ),
    };
    let lat_s = lat_cycles / sys.freq_hz;
    let bw_time = bytes / bw;
    let latency_reqs = bytes / sys.dram.line_bytes as f64 * k.latency_bound_frac;
    let lat_time = latency_reqs * lat_s / a.mlp;
    let mem_s = bw_time + lat_time;

    // Accelerators overlap compute with memory up to the longer of the two.
    compute_s.max(mem_s)
}

/// Speedup of the NDP placement over the compute-centric placement.
pub fn ndp_speedup(k: &KernelDataflow, a: &AccelConfig, sys: &SystemConfig) -> f64 {
    accel_time(k, a, sys, Placement::ComputeCentric) / accel_time(k, a, sys, Placement::Ndp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::{CoreModel, SystemConfig};

    fn sys() -> SystemConfig {
        SystemConfig::host(1, CoreModel::OutOfOrder)
    }

    /// Streaming, bandwidth-hungry kernel (class 1a-like, e.g. gemm with
    /// huge matrices streamed from DRAM).
    fn bw_kernel() -> KernelDataflow {
        KernelDataflow {
            ops_per_elem: 1.0,
            chain_depth: 4.0,
            bytes_per_elem: 24.0,
            elems: 1e7,
            latency_bound_frac: 0.0,
        }
    }

    /// Latency-bound kernel (class 1b-like).
    fn lat_kernel() -> KernelDataflow {
        KernelDataflow {
            ops_per_elem: 4.0,
            chain_depth: 4.0,
            bytes_per_elem: 8.0,
            elems: 1e7,
            latency_bound_frac: 0.5,
        }
    }

    /// Compute-bound kernel (class 2c-like).
    fn compute_kernel() -> KernelDataflow {
        KernelDataflow {
            ops_per_elem: 200.0,
            chain_depth: 4.0,
            bytes_per_elem: 2.0,
            elems: 1e7,
            latency_bound_frac: 0.0,
        }
    }

    #[test]
    fn bw_bound_kernel_gains_from_ndp() {
        let s = ndp_speedup(&bw_kernel(), &AccelConfig::default(), &sys());
        assert!(s > 1.5, "speedup={s}");
    }

    #[test]
    fn latency_bound_kernel_gains_modestly() {
        let s = ndp_speedup(&lat_kernel(), &AccelConfig::default(), &sys());
        assert!(s > 1.05, "speedup={s}");
        assert!(s < ndp_speedup(&bw_kernel(), &AccelConfig::default(), &sys()));
    }

    #[test]
    fn compute_bound_kernel_gains_nothing() {
        let s = ndp_speedup(&compute_kernel(), &AccelConfig::default(), &sys());
        assert!((s - 1.0).abs() < 0.05, "speedup={s}");
    }

    #[test]
    fn time_positive_and_monotone_in_elems() {
        let a = AccelConfig::default();
        let mut k = bw_kernel();
        let t1 = accel_time(&k, &a, &sys(), Placement::Ndp);
        k.elems *= 2.0;
        let t2 = accel_time(&k, &a, &sys(), Placement::Ndp);
        assert!(t1 > 0.0 && t2 > 1.9 * t1);
    }
}
