//! Cooperative cancellation and wall-clock budgets for sweep jobs.
//!
//! A characterization sweep is only as robust as its slowest job: a
//! livelocked replay loop or a pathological simulator config can park a
//! worker lane forever, and the scoped pool then blocks at scope exit.
//! This module provides the primitives the deadline-aware scheduler in
//! [`crate::util::pool`] is built on — no external crates, no OS signal
//! machinery, purely cooperative:
//!
//! * [`CancelToken`] — a cloneable atomic flag a watchdog sets and a job
//!   observes. Cancellation is one-shot and carries a [`CancelReason`].
//! * [`install`] — binds a token to the current thread for the duration
//!   of a job, so deeply nested code (the sim engine's replay loop,
//!   injected hangs) can reach it without threading it through every
//!   signature.
//! * [`poll`] — the observation point. Cheap when not cancelled (one
//!   thread-local read and one relaxed atomic load); on cancellation it
//!   panics with [`CANCEL_MARKER`] in the payload, unwinding the job
//!   back to the pool's `catch_unwind`, which maps the marker onto
//!   `JobErrorKind::TimedOut` / `Cancelled` instead of a plain panic.
//! * [`Deadline`] — a small wall-clock budget type for sweep-wide
//!   limits, plus [`parse_duration`] for CLI flags like
//!   `--job-timeout 2s`.
//!
//! Because a cancelled job exits by unwinding *before* its result is
//! returned, a timed-out profile can never be half-written to a
//! checkpoint: the pool records a `JobError` and the coordinator appends
//! a retryable record instead.

use crate::util::telemetry::{metrics, trace};
use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Why a job was cancelled. Ordered roughly by scope: one job, the
/// whole sweep, the whole process.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CancelReason {
    /// The job exceeded its per-job wall-clock budget (`--job-timeout`).
    JobTimeout,
    /// The sweep exceeded its overall budget (`--sweep-deadline`).
    SweepDeadline,
    /// The process is shutting down.
    Shutdown,
}

impl CancelReason {
    /// Stable lowercase label used in telemetry events and retryable
    /// checkpoint records.
    pub fn label(&self) -> &'static str {
        match self {
            CancelReason::JobTimeout => "job-timeout",
            CancelReason::SweepDeadline => "sweep-deadline",
            CancelReason::Shutdown => "shutdown",
        }
    }
}

// State encoding of a token: 0 = live, otherwise a CancelReason.
const LIVE: u8 = 0;

fn encode(reason: CancelReason) -> u8 {
    match reason {
        CancelReason::JobTimeout => 1,
        CancelReason::SweepDeadline => 2,
        CancelReason::Shutdown => 3,
    }
}

fn decode(state: u8) -> Option<CancelReason> {
    match state {
        1 => Some(CancelReason::JobTimeout),
        2 => Some(CancelReason::SweepDeadline),
        3 => Some(CancelReason::Shutdown),
        _ => None,
    }
}

struct Inner {
    state: AtomicU8,
    /// Timestamp of the cancel call ([`trace::now_us`] clock), so the
    /// latency between cancellation and observation is measurable.
    cancelled_at_us: AtomicU64,
}

/// A cloneable, one-shot cancellation flag shared between a watchdog
/// (which cancels) and a job (which polls). All clones observe the same
/// state.
#[derive(Clone)]
pub struct CancelToken {
    inner: Arc<Inner>,
}

impl Default for CancelToken {
    fn default() -> Self {
        CancelToken::new()
    }
}

impl CancelToken {
    /// A fresh, live token.
    pub fn new() -> CancelToken {
        CancelToken {
            inner: Arc::new(Inner {
                state: AtomicU8::new(LIVE),
                cancelled_at_us: AtomicU64::new(0),
            }),
        }
    }

    /// Cancel with `reason`. One-shot: returns `true` only for the call
    /// that performed the live→cancelled transition; later calls (any
    /// reason) are no-ops returning `false`, so the first reason wins.
    pub fn cancel(&self, reason: CancelReason) -> bool {
        // Stamp first so an observer that sees the state flip always
        // reads a plausible timestamp.
        let now = trace::now_us();
        let won = self
            .inner
            .state
            .compare_exchange(LIVE, encode(reason), Ordering::AcqRel, Ordering::Acquire)
            .is_ok();
        if won {
            self.inner.cancelled_at_us.store(now, Ordering::Release);
        }
        won
    }

    /// True once [`cancel`](CancelToken::cancel) has been called.
    pub fn is_cancelled(&self) -> bool {
        self.inner.state.load(Ordering::Acquire) != LIVE
    }

    /// The winning cancellation reason, if cancelled.
    pub fn reason(&self) -> Option<CancelReason> {
        decode(self.inner.state.load(Ordering::Acquire))
    }

    /// When the token was cancelled, microseconds on the
    /// [`trace::now_us`] clock (0 if still live).
    pub fn cancelled_at_us(&self) -> u64 {
        self.inner.cancelled_at_us.load(Ordering::Acquire)
    }
}

/// Marker embedded in the panic payload of a cancellation unwind, so
/// `catch_unwind` handlers and panic hooks can tell a cooperative
/// cancel from a real crash.
pub const CANCEL_MARKER: &str = "damov-job-cancelled";

thread_local! {
    static CURRENT: RefCell<Option<CancelToken>> = const { RefCell::new(None) };
}

/// RAII guard from [`install`]: restores the previously installed token
/// (if any) on drop.
pub struct TokenGuard {
    prev: Option<CancelToken>,
}

impl Drop for TokenGuard {
    fn drop(&mut self) {
        let prev = self.prev.take();
        CURRENT.with(|c| *c.borrow_mut() = prev);
    }
}

/// Install `token` as the calling thread's cancellation context until
/// the returned guard drops. Nested installs stack.
pub fn install(token: CancelToken) -> TokenGuard {
    let prev = CURRENT.with(|c| c.borrow_mut().replace(token));
    TokenGuard { prev }
}

/// The token installed on this thread, if any.
pub fn current() -> Option<CancelToken> {
    CURRENT.with(|c| c.borrow().clone())
}

/// True when the calling thread runs under an installed token (i.e. a
/// cooperative hang can eventually be cancelled).
pub fn has_token() -> bool {
    CURRENT.with(|c| c.borrow().is_some())
}

/// Non-panicking check: is this thread's job cancelled?
pub fn cancelled() -> bool {
    CURRENT.with(|c| c.borrow().as_ref().map(|t| t.is_cancelled()).unwrap_or(false))
}

/// The cancellation observation point. Call this from long loops
/// (amortized — e.g. every 64K replayed events). No-op without an
/// installed token or while the token is live; once cancelled it
/// records the cancel→observe latency and panics with
/// [`CANCEL_MARKER`], unwinding the job back to the pool.
pub fn poll() {
    // Extract the verdict before panicking so the RefCell borrow is
    // released prior to the unwind.
    let hit = CURRENT.with(|c| {
        c.borrow()
            .as_ref()
            .and_then(|t| t.reason().map(|r| (r, t.cancelled_at_us())))
    });
    let Some((reason, at)) = hit else {
        return;
    };
    metrics::counter("cancel.observed").incr();
    if at != 0 {
        // at == 0 only in the sliver between the state flip and the
        // timestamp store; skip the sample rather than record garbage.
        metrics::histogram("cancel.latency_us").record(trace::now_us().saturating_sub(at));
    }
    panic!("{CANCEL_MARKER}: {}", reason.label());
}

/// A wall-clock budget with an absolute expiry instant.
#[derive(Debug, Clone, Copy)]
pub struct Deadline {
    at: Instant,
}

impl Deadline {
    /// A deadline `budget` from now.
    pub fn after(budget: Duration) -> Deadline {
        Deadline { at: Instant::now() + budget }
    }

    /// True once the budget is exhausted.
    pub fn expired(&self) -> bool {
        Instant::now() >= self.at
    }

    /// Time left before expiry (zero once expired).
    pub fn remaining(&self) -> Duration {
        self.at.saturating_duration_since(Instant::now())
    }
}

/// Parse a human-friendly duration for CLI flags: a non-negative number
/// with an optional unit suffix `us` / `ms` / `s` (default) / `m` / `h`,
/// e.g. `2s`, `1500ms`, `0.5h`, `90`.
pub fn parse_duration(s: &str) -> Result<Duration, String> {
    let t = s.trim();
    if t.is_empty() {
        return Err("empty duration".to_string());
    }
    let split = t
        .find(|c: char| c.is_ascii_alphabetic())
        .unwrap_or(t.len());
    let (num, unit) = t.split_at(split);
    let v: f64 = num
        .trim()
        .parse()
        .map_err(|e| format!("bad duration {s:?}: {e}"))?;
    if !v.is_finite() || v < 0.0 {
        return Err(format!("duration {s:?} must be finite and non-negative"));
    }
    let secs = match unit.trim() {
        "" | "s" | "sec" | "secs" => v,
        "us" => v / 1_000_000.0,
        "ms" => v / 1000.0,
        "m" | "min" => v * 60.0,
        "h" => v * 3600.0,
        other => return Err(format!("unknown duration unit {other:?} in {s:?}")),
    };
    Ok(Duration::from_secs_f64(secs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    #[test]
    fn token_cancel_is_one_shot_and_first_reason_wins() {
        let t = CancelToken::new();
        assert!(!t.is_cancelled());
        assert_eq!(t.reason(), None);
        assert!(t.cancel(CancelReason::JobTimeout));
        assert!(!t.cancel(CancelReason::SweepDeadline), "second cancel must lose");
        assert!(t.is_cancelled());
        assert_eq!(t.reason(), Some(CancelReason::JobTimeout));
        // Clones observe the same state.
        assert!(t.clone().is_cancelled());
    }

    #[test]
    fn poll_is_inert_without_token_and_unwinds_with_marker_when_cancelled() {
        poll(); // no token installed: must not panic
        let t = CancelToken::new();
        {
            let _g = install(t.clone());
            assert!(has_token());
            poll(); // live token: still no panic
            t.cancel(CancelReason::SweepDeadline);
            assert!(cancelled());
            let err = catch_unwind(AssertUnwindSafe(poll)).unwrap_err();
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_default();
            assert!(msg.contains(CANCEL_MARKER), "payload: {msg:?}");
            assert!(msg.contains("sweep-deadline"), "payload: {msg:?}");
        }
        assert!(!has_token(), "guard must uninstall the token");
    }

    #[test]
    fn install_restores_previous_token() {
        let outer = CancelToken::new();
        let inner = CancelToken::new();
        let _g1 = install(outer.clone());
        outer.cancel(CancelReason::Shutdown);
        {
            let _g2 = install(inner);
            assert!(!cancelled(), "inner token shadows the outer one");
        }
        assert!(cancelled(), "outer token restored after inner guard drops");
    }

    #[test]
    fn deadline_expires() {
        let d = Deadline::after(Duration::from_millis(0));
        assert!(d.expired());
        assert_eq!(d.remaining(), Duration::ZERO);
        let far = Deadline::after(Duration::from_secs(3600));
        assert!(!far.expired());
        assert!(far.remaining() > Duration::from_secs(3000));
    }

    #[test]
    fn parse_duration_accepts_units_and_rejects_garbage() {
        assert_eq!(parse_duration("2s").unwrap(), Duration::from_secs(2));
        assert_eq!(parse_duration("90").unwrap(), Duration::from_secs(90));
        assert_eq!(parse_duration("1500ms").unwrap(), Duration::from_millis(1500));
        assert_eq!(parse_duration("250us").unwrap(), Duration::from_micros(250));
        assert_eq!(parse_duration("2m").unwrap(), Duration::from_secs(120));
        assert_eq!(parse_duration(" 1.5h ").unwrap(), Duration::from_secs(5400));
        assert!(parse_duration("").is_err());
        assert!(parse_duration("-3s").is_err());
        assert!(parse_duration("fast").is_err());
        assert!(parse_duration("10 parsecs").is_err());
    }
}
