//! Deterministic fault injection for robustness testing.
//!
//! Long characterization sweeps must survive panicking workers, torn
//! writes, and slow I/O. This module lets tests (and brave users) inject
//! those failures *deterministically* so the recovery paths — retry,
//! checkpoint/resume, graceful degradation — can be exercised and the
//! recovered results compared byte-for-byte against a fault-free run.
//!
//! Activation, in precedence order:
//! 1. a programmatic override installed with [`set_override`] (tests);
//! 2. the `DAMOV_FAULT_SPEC` environment variable, e.g.
//!    `DAMOV_FAULT_SPEC=panic:0.05,io:0.1,delay:0.2,hang:0.1,seed:42`.
//!
//! Determinism: every injection decision is a pure hash of
//! `(seed, site, key, attempt)` — independent of thread scheduling. The
//! *attempt* counter (per site/key, process-global) makes retries of the
//! same job re-roll, so a bounded-retry loop converges instead of hitting
//! the same injected panic forever. Because faults only abort or delay
//! work — never alter computed values — a sweep that survives injection
//! produces results identical to a clean sweep.
//!
//! Injection sites used across the crate:
//! * `"sim"` — entry of `methodology::step3::profile_function` (panics,
//!   latency, and hangs; exercises `pool::par_map_catch` isolation +
//!   retry and the deadline watchdog);
//! * `"store"` — results-store writes (I/O errors; exercises atomic
//!   save and checkpoint degradation);
//! * `"pjrt-load"` — artifact loading (I/O errors; exercises the
//!   native-analytics fallback).

use crate::util::cancel;
use crate::util::json::Json;
use crate::util::rng::mix64;
use crate::util::telemetry::{self, metrics, Level};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock, RwLock};

/// Per-site fault probabilities plus the seed of the decision hash.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct FaultSpec {
    /// Probability that an instrumented site panics.
    pub panic_p: f64,
    /// Probability that an instrumented I/O site returns an error.
    pub io_p: f64,
    /// Probability that an instrumented site sleeps 1–5 ms.
    pub delay_p: f64,
    /// Probability that an instrumented site hangs (sleep-loops) until
    /// its job is cancelled — exercises the deadline/watchdog machinery.
    pub hang_p: f64,
    /// Seed of the deterministic decision hash.
    pub seed: u64,
}

impl FaultSpec {
    /// Parse the `DAMOV_FAULT_SPEC` syntax: comma-separated
    /// `kind:value` entries with kinds `panic`, `io`, `delay`, `hang`
    /// (f64 probabilities in [0,1]) and `seed` (u64).
    pub fn parse(s: &str) -> Result<FaultSpec, String> {
        let mut spec = FaultSpec::default();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (kind, val) = part
                .split_once(':')
                .ok_or_else(|| format!("fault spec entry {part:?} is not kind:value"))?;
            match kind.trim() {
                "seed" => {
                    spec.seed = val
                        .trim()
                        .parse::<u64>()
                        .map_err(|e| format!("bad seed {val:?}: {e}"))?;
                }
                kind @ ("panic" | "io" | "delay" | "hang") => {
                    let p = val
                        .trim()
                        .parse::<f64>()
                        .map_err(|e| format!("bad probability {val:?}: {e}"))?;
                    if !(0.0..=1.0).contains(&p) {
                        return Err(format!("probability {p} for {kind:?} outside [0,1]"));
                    }
                    match kind {
                        "panic" => spec.panic_p = p,
                        "io" => spec.io_p = p,
                        "delay" => spec.delay_p = p,
                        _ => spec.hang_p = p,
                    }
                }
                other => return Err(format!("unknown fault kind {other:?}")),
            }
        }
        Ok(spec)
    }

    /// True if any fault kind can fire.
    pub fn is_active(&self) -> bool {
        self.panic_p > 0.0 || self.io_p > 0.0 || self.delay_p > 0.0 || self.hang_p > 0.0
    }
}

/// Marker embedded in every injected panic/error message, so handlers
/// and panic hooks can tell injected faults from real ones.
pub const FAULT_MARKER: &str = "damov-fault-injected";

// Some(spec): forced on. None (initial): fall through to the env var.
// Tests install overrides so parallel test binaries don't race on env.
static OVERRIDE: RwLock<Option<FaultSpec>> = RwLock::new(None);
static INJECTED: AtomicU64 = AtomicU64::new(0);

fn attempts() -> &'static Mutex<HashMap<u64, u64>> {
    static A: OnceLock<Mutex<HashMap<u64, u64>>> = OnceLock::new();
    A.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Install a programmatic fault spec that takes precedence over the
/// environment. Intended for tests.
pub fn set_override(spec: Option<FaultSpec>) {
    *OVERRIDE.write().unwrap() = spec;
}

/// Forget all per-site attempt counters (test hygiene: makes injection
/// decisions start from attempt 0 again).
pub fn reset_attempts() {
    attempts().lock().unwrap().clear();
}

/// Total number of faults injected by this process so far.
pub fn injected_count() -> u64 {
    INJECTED.load(Ordering::Relaxed)
}

/// The active fault spec, if any: the override when installed, else a
/// freshly parsed `DAMOV_FAULT_SPEC`. Malformed env specs are reported
/// once per call and treated as inactive (a broken knob must not take
/// down a clean sweep).
pub fn current() -> Option<FaultSpec> {
    if let Some(spec) = *OVERRIDE.read().unwrap() {
        return spec.is_active().then_some(spec);
    }
    let raw = std::env::var("DAMOV_FAULT_SPEC").ok()?;
    match FaultSpec::parse(&raw) {
        Ok(spec) => spec.is_active().then_some(spec),
        Err(e) => {
            telemetry::warn(
                "fault-spec",
                &[("detail", Json::from(format!("ignoring malformed DAMOV_FAULT_SPEC: {e}")))],
            );
            None
        }
    }
}

/// Stable 64-bit key for a string identity (function code, path, ...).
pub fn key_of(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64; // FNV-1a offset basis
    for b in s.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn site_key(site: &str, key: u64) -> u64 {
    mix64(key_of(site) ^ mix64(key))
}

/// Deterministic uniform draw in [0,1) for (spec.seed, site, key, kind,
/// attempt). The attempt index is a process-global counter per
/// (site, key, kind) so retries re-roll. Returns the draw and the
/// attempt index it was made for.
fn draw(spec: &FaultSpec, site: &str, key: u64, kind_salt: u64) -> (f64, u64) {
    let sk = site_key(site, key) ^ mix64(kind_salt);
    let attempt = {
        let mut m = attempts().lock().unwrap();
        let c = m.entry(sk).or_insert(0);
        let a = *c;
        *c += 1;
        a
    };
    let h = mix64(spec.seed ^ sk ^ mix64(attempt.wrapping_add(0x9E37_79B9_7F4A_7C15)));
    ((h >> 11) as f64 * (1.0 / (1u64 << 53) as f64), attempt)
}

/// Record one injection decision as telemetry: counters plus a
/// structured event (injections at info, passes at debug) so a faulted
/// run's event log fully explains its retries.
fn record_decision(kind: &'static str, site: &str, key: u64, attempt: u64, inject: bool) {
    metrics::counter("fault.decisions").incr();
    let level = if inject {
        metrics::counter(&format!("fault.injected_{kind}")).incr();
        Level::Info
    } else {
        Level::Debug
    };
    if !telemetry::log::enabled(level) {
        return;
    }
    telemetry::log::emit(
        level,
        "fault",
        &[
            ("kind", Json::from(kind)),
            ("site", Json::from(site)),
            ("key", Json::from(format!("{key:#x}"))),
            ("attempt", Json::from(attempt)),
            ("verdict", Json::from(if inject { "inject" } else { "pass" })),
        ],
    );
}

/// Panic (deterministically) with probability `panic_p` at this site.
pub fn maybe_panic(site: &str, key: u64) {
    if let Some(spec) = current() {
        if spec.panic_p > 0.0 {
            let (v, attempt) = draw(&spec, site, key, 1);
            let inject = v < spec.panic_p;
            record_decision("panic", site, key, attempt, inject);
            if inject {
                INJECTED.fetch_add(1, Ordering::Relaxed);
                panic!("{FAULT_MARKER}: panic at site {site:?} (key {key:#x})");
            }
        }
    }
}

/// Return an injected I/O error with probability `io_p` at this site.
pub fn maybe_io(site: &str, key: u64) -> std::io::Result<()> {
    if let Some(spec) = current() {
        if spec.io_p > 0.0 {
            let (v, attempt) = draw(&spec, site, key, 2);
            let inject = v < spec.io_p;
            record_decision("io", site, key, attempt, inject);
            if inject {
                INJECTED.fetch_add(1, Ordering::Relaxed);
                return Err(std::io::Error::new(
                    std::io::ErrorKind::Other,
                    format!("{FAULT_MARKER}: io error at site {site:?} (key {key:#x})"),
                ));
            }
        }
    }
    Ok(())
}

/// Hang with probability `hang_p` at this site: sleep-loop in ~1 ms
/// steps, checking the job's cancel token each step, until a watchdog
/// cancels the job — whereupon [`cancel::poll`] unwinds with the cancel
/// marker. Models a livelocked replay or stalled I/O call for the
/// deadline machinery (kind salt 4). Without an installed token (no
/// `--job-timeout`/`--sweep-deadline` active) a true hang would wedge
/// the process, so the injection degrades to a bounded 25 ms stall plus
/// a structured warning.
pub fn maybe_hang(site: &str, key: u64) {
    if let Some(spec) = current() {
        if spec.hang_p > 0.0 {
            let (v, attempt) = draw(&spec, site, key, 4);
            let inject = v < spec.hang_p;
            record_decision("hang", site, key, attempt, inject);
            if inject {
                INJECTED.fetch_add(1, Ordering::Relaxed);
                if !cancel::has_token() {
                    telemetry::warn(
                        "fault",
                        &[(
                            "detail",
                            Json::from(format!(
                                "hang injected at site {site:?} (key {key:#x}) without a \
                                 cancellation context; stalling 25 ms instead of hanging"
                            )),
                        )],
                    );
                    std::thread::sleep(std::time::Duration::from_millis(25));
                    return;
                }
                loop {
                    cancel::poll();
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
            }
        }
    }
}

/// Sleep 1–5 ms (deterministic duration) with probability `delay_p`.
pub fn maybe_delay(site: &str, key: u64) {
    if let Some(spec) = current() {
        if spec.delay_p > 0.0 {
            let (v, attempt) = draw(&spec, site, key, 3);
            let inject = v < spec.delay_p;
            record_decision("delay", site, key, attempt, inject);
            if inject {
                INJECTED.fetch_add(1, Ordering::Relaxed);
                let ms = 1 + (site_key(site, key) % 5);
                std::thread::sleep(std::time::Duration::from_millis(ms));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_spec() {
        let s = FaultSpec::parse("panic:0.05, io:0.1,delay:0.2,seed:42").unwrap();
        assert!((s.panic_p - 0.05).abs() < 1e-12);
        assert!((s.io_p - 0.1).abs() < 1e-12);
        assert!((s.delay_p - 0.2).abs() < 1e-12);
        assert_eq!(s.seed, 42);
        assert!(s.is_active());
    }

    #[test]
    fn parse_hang_kind() {
        let s = FaultSpec::parse("hang:0.2,seed:7").unwrap();
        assert!((s.hang_p - 0.2).abs() < 1e-12);
        assert_eq!(s.seed, 7);
        assert!(s.is_active());
        assert!(FaultSpec::parse("hang:2").is_err());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultSpec::parse("panic").is_err());
        assert!(FaultSpec::parse("panic:1.5").is_err());
        assert!(FaultSpec::parse("frobnicate:0.1").is_err());
        assert!(FaultSpec::parse("seed:-1").is_err());
    }

    #[test]
    fn empty_spec_is_inactive() {
        let s = FaultSpec::parse("").unwrap();
        assert!(!s.is_active());
    }

    #[test]
    fn draws_are_deterministic_per_attempt() {
        let spec = FaultSpec {
            panic_p: 0.5,
            seed: 7,
            ..FaultSpec::default()
        };
        reset_attempts();
        let a0 = draw(&spec, "unit-test-site", 11, 1).0;
        let a1 = draw(&spec, "unit-test-site", 11, 1).0;
        reset_attempts();
        let b0 = draw(&spec, "unit-test-site", 11, 1).0;
        let b1 = draw(&spec, "unit-test-site", 11, 1).0;
        assert_eq!(a0.to_bits(), b0.to_bits());
        assert_eq!(a1.to_bits(), b1.to_bits());
        assert_ne!(a0.to_bits(), a1.to_bits(), "retries must re-roll");
    }

    #[test]
    fn injection_rate_tracks_probability() {
        let spec = FaultSpec {
            io_p: 0.3,
            seed: 99,
            ..FaultSpec::default()
        };
        let mut hits = 0;
        for key in 0..2000u64 {
            if draw(&spec, "rate-site", key, 2).0 < spec.io_p {
                hits += 1;
            }
        }
        // 2000 Bernoulli(0.3) draws: expect ~600, allow wide slack.
        assert!((450..750).contains(&hits), "hits={hits}");
    }

    #[test]
    fn key_of_distinguishes_strings() {
        assert_ne!(key_of("STRTriad"), key_of("STRCpy"));
        assert_eq!(key_of("STRTriad"), key_of("STRTriad"));
    }
}
