//! Structured, leveled event log.
//!
//! Every emission is one event: a severity [`Level`], a short `kind`
//! tag (`"degraded"`, `"retry"`, `"fault"`, ...), and a list of
//! key/value fields. Two renderings of the same event exist:
//!
//! - **JSONL** (machine form): `{"ts_us":..,"level":"warn","kind":..,
//!   "fields":{..}}`, one object per line, written when `DAMOV_LOG`
//!   names a file (appended) or is `-` (stderr).
//! - **Text** (human form): the pre-telemetry stderr format, e.g.
//!   `warning: [degraded] component=pjrt fallback=native detail="..."`,
//!   used when `DAMOV_LOG` is unset.
//!
//! Exactly one rendering is active at a time, so nothing prints twice.
//! `DAMOV_LOG_LEVEL=error|warn|info|debug` filters both (default
//! `info`; setting the legacy `DAMOV_DEBUG` implies `debug`).
//! Timestamps share the monotonic clock of [`super::trace`] so log
//! lines correlate with trace spans.

use crate::util::json::Json;
use std::fs::File;
use std::io::Write;
use std::path::Path;
use std::sync::{Mutex, OnceLock};

/// Event severity, ordered from most to least severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn label(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    pub fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }
}

enum Sink {
    /// Human-readable text to stderr (default).
    Text,
    /// JSONL to stderr (`DAMOV_LOG=-`).
    JsonStderr,
    /// JSONL appended to a file (`DAMOV_LOG=<path>`).
    JsonFile(File),
}

struct State {
    level: Level,
    sink: Sink,
}

fn state() -> &'static Mutex<State> {
    static S: OnceLock<Mutex<State>> = OnceLock::new();
    S.get_or_init(|| {
        let level = std::env::var("DAMOV_LOG_LEVEL")
            .ok()
            .and_then(|s| Level::parse(&s))
            .unwrap_or(if std::env::var("DAMOV_DEBUG").is_ok() {
                Level::Debug
            } else {
                Level::Info
            });
        let sink = match std::env::var("DAMOV_LOG") {
            Ok(p) if p == "-" => Sink::JsonStderr,
            Ok(p) if !p.is_empty() => {
                match File::options().create(true).append(true).open(&p) {
                    Ok(f) => Sink::JsonFile(f),
                    Err(e) => {
                        eprintln!("warning: [log] cannot open DAMOV_LOG={p}: {e}");
                        Sink::Text
                    }
                }
            }
            _ => Sink::Text,
        };
        Mutex::new(State { level, sink })
    })
}

fn lock() -> std::sync::MutexGuard<'static, State> {
    state().lock().unwrap_or_else(|p| p.into_inner())
}

/// Would an event at `level` be emitted? Use to skip building
/// expensive debug fields.
pub fn enabled(level: Level) -> bool {
    level <= lock().level
}

/// Override the level filter (tests, embedders).
pub fn set_level(level: Level) {
    lock().level = level;
}

/// Redirect the log: `Some(path)` appends JSONL to the file, `None`
/// restores human-readable text on stderr. For tests and embedders.
pub fn set_file(path: Option<&Path>) -> std::io::Result<()> {
    let sink = match path {
        Some(p) => Sink::JsonFile(File::options().create(true).append(true).open(p)?),
        None => Sink::Text,
    };
    lock().sink = sink;
    Ok(())
}

fn render_field_value(v: &Json) -> String {
    match v {
        Json::Str(s) => {
            let plain = !s.is_empty()
                && s.chars().all(|c| c.is_ascii_graphic() && c != '"' && c != '\\');
            if plain {
                s.clone()
            } else {
                format!("{s:?}")
            }
        }
        other => other.to_string_compact(),
    }
}

fn render_text(level: Level, kind: &str, fields: &[(&str, Json)]) -> String {
    let prefix = match level {
        Level::Error => "error:",
        Level::Warn => "warning:",
        Level::Info => "[damov]",
        Level::Debug => "[debug]",
    };
    let mut line = format!("{prefix} [{kind}]");
    for (k, v) in fields {
        if *k == "msg" {
            if let Json::Str(s) = v {
                line.push(' ');
                line.push_str(s);
                continue;
            }
        }
        line.push(' ');
        line.push_str(k);
        line.push('=');
        line.push_str(&render_field_value(v));
    }
    line
}

fn render_jsonl(level: Level, kind: &str, fields: &[(&str, Json)]) -> String {
    let mut f = Json::obj();
    for (k, v) in fields {
        f.set(*k, v.clone());
    }
    let mut j = Json::obj();
    j.set("ts_us", super::trace::now_us())
        .set("level", level.label())
        .set("kind", kind)
        .set("fields", f);
    j.to_string_compact()
}

/// Emit one structured event. Filtered by the active level; routed to
/// exactly one sink. Holding the state lock across the write keeps
/// lines from interleaving under `par_map_catch`.
pub fn emit(level: Level, kind: &str, fields: &[(&str, Json)]) {
    let mut st = lock();
    if level > st.level {
        return;
    }
    match &mut st.sink {
        Sink::Text => eprintln!("{}", render_text(level, kind, fields)),
        Sink::JsonStderr => eprintln!("{}", render_jsonl(level, kind, fields)),
        Sink::JsonFile(f) => {
            let line = render_jsonl(level, kind, fields);
            // A full disk must not take down the sweep; drop the line.
            let _ = writeln!(f, "{line}");
            let _ = f.flush();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse_and_order() {
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn text_rendering_matches_legacy_format() {
        let line = render_text(
            Level::Warn,
            "degraded",
            &[
                ("component", Json::from("pjrt")),
                ("fallback", Json::from("native")),
                ("detail", Json::from("load failed: no plugin")),
            ],
        );
        assert_eq!(
            line,
            "warning: [degraded] component=pjrt fallback=native \
             detail=\"load failed: no plugin\""
        );
    }

    #[test]
    fn msg_field_renders_bare() {
        let line = render_text(
            Level::Info,
            "progress",
            &[("msg", Json::from("profiling 7 functions"))],
        );
        assert_eq!(line, "[damov] [progress] profiling 7 functions");
    }

    #[test]
    fn jsonl_rendering_is_parseable() {
        let line = render_jsonl(
            Level::Error,
            "job-failed",
            &[("code", Json::from("STRCpy")), ("attempts", Json::from(3u64))],
        );
        let j = Json::parse(&line).expect("valid json");
        assert_eq!(j.get("level").and_then(Json::as_str), Some("error"));
        assert_eq!(j.get("kind").and_then(Json::as_str), Some("job-failed"));
        let f = j.get("fields").expect("fields");
        assert_eq!(f.get("code").and_then(Json::as_str), Some("STRCpy"));
        assert_eq!(f.get("attempts").and_then(Json::as_f64), Some(3.0));
    }
}
