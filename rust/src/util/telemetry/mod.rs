//! Telemetry for the characterization pipeline: a thread-safe metrics
//! registry ([`metrics`]), Chrome-trace spans ([`trace`]), and a
//! structured leveled event log ([`log`]).
//!
//! Environment variables (see `docs/telemetry.md`):
//!
//! - `DAMOV_TRACE=<path>` — export a Chrome trace-event JSON file.
//! - `DAMOV_LOG=<path>|-` — structured JSONL event log (file or stderr);
//!   unset keeps the human-readable text rendering on stderr.
//! - `DAMOV_LOG_LEVEL=error|warn|info|debug` — event filter (default
//!   `info`; legacy `DAMOV_DEBUG` implies `debug`).
//!
//! Telemetry is observational only: simulated results are byte-identical
//! whether it is enabled or not.

pub mod log;
pub mod metrics;
pub mod trace;

pub use log::Level;

use crate::util::json::Json;
use std::time::Instant;

/// Initialize all sinks from the environment. Called once at CLI
/// startup; safe to call again (later calls are no-ops).
pub fn init_from_env() {
    trace::init_from_env();
    let trace_on = trace::is_enabled();
    let log_path = std::env::var("DAMOV_LOG").ok().filter(|p| !p.is_empty());
    if trace_on || log_path.is_some() {
        let mut fields: Vec<(&str, Json)> = Vec::new();
        if let Some(p) = trace::path() {
            fields.push(("trace", Json::from(p.display().to_string())));
        }
        if let Some(p) = &log_path {
            fields.push(("log", Json::from(p.as_str())));
        }
        log::emit(Level::Info, "telemetry", &fields);
    }
}

/// Flush buffered trace events to `DAMOV_TRACE` (if configured).
pub fn flush() {
    if !trace::is_enabled() {
        return;
    }
    let events = trace::buffered_events();
    match trace::flush() {
        Ok(Some(p)) => log::emit(
            Level::Info,
            "telemetry",
            &[
                ("trace", Json::from(p.display().to_string())),
                ("events", Json::from(events as u64)),
            ],
        ),
        Ok(None) => {}
        Err(e) => log::emit(
            Level::Warn,
            "telemetry",
            &[("detail", Json::from(format!("trace flush failed: {e}")))],
        ),
    }
}

/// Emit an error-level event.
pub fn error(kind: &str, fields: &[(&str, Json)]) {
    log::emit(Level::Error, kind, fields);
}

/// Emit a warn-level event.
pub fn warn(kind: &str, fields: &[(&str, Json)]) {
    log::emit(Level::Warn, kind, fields);
}

/// Emit an info-level event.
pub fn info(kind: &str, fields: &[(&str, Json)]) {
    log::emit(Level::Info, kind, fields);
}

/// Emit a debug-level event.
pub fn debug(kind: &str, fields: &[(&str, Json)]) {
    log::emit(Level::Debug, kind, fields);
}

/// A trace span that also records its wall-clock duration into the
/// `span.<name>.us` histogram, so `damov report telemetry` shows where
/// time went even when no trace file was requested.
pub struct TimedSpan {
    _trace: trace::Span,
    start: Instant,
    metric: String,
}

impl Drop for TimedSpan {
    fn drop(&mut self) {
        let us = self.start.elapsed().as_micros() as u64;
        metrics::histogram(&self.metric).record(us);
    }
}

/// Open a timed span with no trace args.
pub fn span(name: &str) -> TimedSpan {
    span_args(name, Vec::new())
}

/// Open a timed span with Chrome-trace args.
pub fn span_args(name: &str, args: Vec<(String, Json)>) -> TimedSpan {
    TimedSpan {
        _trace: trace::span_args(name, args),
        start: Instant::now(),
        metric: format!("span.{name}.us"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timed_span_records_duration_histogram() {
        {
            let _s = span("unit-facade");
        }
        let h = metrics::histogram("span.unit-facade.us");
        assert!(h.count() >= 1);
    }
}
