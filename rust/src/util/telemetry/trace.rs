//! Span-based tracing with Chrome trace-event JSON export.
//!
//! Activated by `DAMOV_TRACE=<path>` (or programmatically via
//! [`enable`], which tests use to avoid racing on the environment).
//! When inactive, a span costs one relaxed atomic load.
//!
//! Every span emits a `B`/`E` duration-event pair on the lane (`tid`)
//! of the thread that opened it; worker threads of the sweep pool
//! register named lanes (`worker-0`, `worker-1`, ...) so the exported
//! trace shows per-worker timelines. [`flush`] sorts the buffered
//! events by timestamp and writes `{"traceEvents": [...]}` — loadable
//! directly in Perfetto (https://ui.perfetto.dev) or `chrome://tracing`.
//!
//! Timestamps are microseconds on a process-wide monotonic clock
//! ([`now_us`]); the structured event log shares the same clock so log
//! lines can be correlated with trace spans.

use crate::util::json::Json;
use std::cell::Cell;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// One buffered trace event.
struct Ev {
    /// Phase: 'B' (span begin), 'E' (span end), 'M' (metadata),
    /// 'i' (instant).
    ph: char,
    name: String,
    ts: u64,
    tid: u64,
    args: Vec<(String, Json)>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static EVENTS: Mutex<Vec<Ev>> = Mutex::new(Vec::new());
static PATH: Mutex<Option<PathBuf>> = Mutex::new(None);
static NEXT_LANE: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static LANE: Cell<u64> = const { Cell::new(0) };
}

fn epoch() -> Instant {
    static T: OnceLock<Instant> = OnceLock::new();
    *T.get_or_init(Instant::now)
}

/// Microseconds since the process-wide telemetry epoch (first use).
pub fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

/// Read `DAMOV_TRACE` once and activate the sink if it names a path.
pub fn init_from_env() {
    static INIT: OnceLock<()> = OnceLock::new();
    INIT.get_or_init(|| {
        let _ = epoch(); // pin the clock epoch to process start
        if let Ok(p) = std::env::var("DAMOV_TRACE") {
            if !p.is_empty() {
                *PATH.lock().unwrap() = Some(PathBuf::from(p));
                ENABLED.store(true, Ordering::Relaxed);
            }
        }
    });
}

/// True when spans are being recorded.
pub fn is_enabled() -> bool {
    init_from_env();
    ENABLED.load(Ordering::Relaxed)
}

/// Programmatic activation (tests, embedders). `None` buffers events
/// without a file destination; retrieve them with [`take_events_json`].
pub fn enable(path: Option<PathBuf>) {
    init_from_env();
    *PATH.lock().unwrap() = path;
    ENABLED.store(true, Ordering::Relaxed);
}

/// Stop recording spans (buffered events are kept until taken/flushed).
pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

/// The configured export path, if any.
pub fn path() -> Option<PathBuf> {
    PATH.lock().unwrap().clone()
}

/// Number of currently buffered events.
pub fn buffered_events() -> usize {
    EVENTS.lock().unwrap().len()
}

fn push(ev: Ev) {
    EVENTS.lock().unwrap().push(ev);
}

/// Lane (Chrome `tid`) of the calling thread, assigned on first use.
/// Emits a `thread_name` metadata event so the lane is labeled.
fn lane() -> u64 {
    LANE.with(|l| {
        let v = l.get();
        if v != 0 {
            return v;
        }
        let id = NEXT_LANE.fetch_add(1, Ordering::Relaxed);
        l.set(id);
        let name = std::thread::current()
            .name()
            .map(str::to_string)
            .unwrap_or_else(|| format!("thread-{id}"));
        push(Ev {
            ph: 'M',
            name,
            ts: now_us(),
            tid: id,
            args: Vec::new(),
        });
        id
    })
}

/// Label the calling thread's lane (the sweep pool labels its workers
/// `worker-<k>`). No-op when tracing is inactive.
pub fn set_thread_label(label: &str) {
    if !is_enabled() {
        return;
    }
    let tid = lane();
    push(Ev {
        ph: 'M',
        name: label.to_string(),
        ts: now_us(),
        tid,
        args: Vec::new(),
    });
}

/// Record a point-in-time marker (Chrome instant event, thread scope)
/// on the calling thread's lane — e.g. a watchdog cancellation. No-op
/// when tracing is inactive.
pub fn instant(name: &str, args: Vec<(String, Json)>) {
    if !is_enabled() {
        return;
    }
    let tid = lane();
    push(Ev {
        ph: 'i',
        name: name.to_string(),
        ts: now_us(),
        tid,
        args,
    });
}

/// RAII span: records `B` on creation and `E` on drop, on the creating
/// thread's lane. Inert (zero events) when tracing is inactive at
/// creation time.
pub struct Span {
    tid: u64,
    live: bool,
}

/// Open a span with no arguments.
pub fn span(name: &'static str) -> Span {
    span_args(name, Vec::new())
}

/// Open a span with Chrome `args` shown in the trace viewer.
pub fn span_args(name: &str, args: Vec<(String, Json)>) -> Span {
    if !is_enabled() {
        return Span { tid: 0, live: false };
    }
    let tid = lane();
    push(Ev {
        ph: 'B',
        name: name.to_string(),
        ts: now_us(),
        tid,
        args,
    });
    Span { tid, live: true }
}

impl Drop for Span {
    fn drop(&mut self) {
        if self.live {
            // Unconditional: a span opened while tracing was active must
            // close its B event even if tracing was disabled meanwhile.
            push(Ev {
                ph: 'E',
                name: String::new(),
                ts: now_us(),
                tid: self.tid,
                args: Vec::new(),
            });
        }
    }
}

fn ev_to_json(ev: &Ev) -> Json {
    let mut j = Json::obj();
    j.set("ph", ev.ph.to_string().as_str())
        .set("ts", ev.ts)
        .set("pid", 1u64)
        .set("tid", ev.tid)
        .set("cat", "damov");
    if ev.ph == 'M' {
        let mut args = Json::obj();
        args.set("name", ev.name.as_str());
        j.set("name", "thread_name").set("args", args);
    } else {
        if !ev.name.is_empty() {
            j.set("name", ev.name.as_str());
        }
        if ev.ph == 'i' {
            // Chrome instant events need an explicit scope; "t" pins the
            // marker to its thread lane.
            j.set("s", "t");
        }
        if !ev.args.is_empty() {
            let mut args = Json::obj();
            for (k, v) in &ev.args {
                args.set(k, v.clone());
            }
            j.set("args", args);
        }
    }
    j
}

/// Drain the buffer into a Chrome trace document
/// (`{"traceEvents": [...]}`), sorted by timestamp (stable, so each
/// lane's `B`/`E` nesting order is preserved for equal timestamps).
pub fn take_events_json() -> Json {
    let mut events = std::mem::take(&mut *EVENTS.lock().unwrap());
    events.sort_by_key(|e| e.ts);
    let arr: Vec<Json> = events.iter().map(ev_to_json).collect();
    let mut doc = Json::obj();
    doc.set("traceEvents", Json::Arr(arr))
        .set("displayTimeUnit", "ms");
    doc
}

/// Write buffered events to the configured `DAMOV_TRACE` path (if one
/// is set) and clear the buffer. Returns the path written, `None` when
/// no destination is configured (buffer-only mode keeps the events).
pub fn flush() -> std::io::Result<Option<PathBuf>> {
    let dest = path();
    let Some(p) = dest else {
        return Ok(None);
    };
    let doc = take_events_json();
    std::fs::write(&p, doc.to_string_compact())?;
    Ok(Some(p))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex as StdMutex;

    // Tracing state is process-global; serialize the tests that toggle it.
    static GATE: StdMutex<()> = StdMutex::new(());

    #[test]
    fn inert_when_disabled() {
        let _g = GATE.lock().unwrap_or_else(|p| p.into_inner());
        disable();
        let before = buffered_events();
        {
            let _s = span("unit-disabled");
        }
        assert_eq!(buffered_events(), before);
    }

    #[test]
    fn instants_record_name_and_thread_scope() {
        let _g = GATE.lock().unwrap_or_else(|p| p.into_inner());
        let _ = take_events_json(); // start from an empty buffer
        enable(None);
        instant("unit-instant", vec![("job".to_string(), Json::from(3u64))]);
        disable();
        let doc = take_events_json();
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let inst: Vec<&Json> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("i"))
            .collect();
        assert_eq!(inst.len(), 1);
        assert_eq!(inst[0].get("name").and_then(Json::as_str), Some("unit-instant"));
        assert_eq!(inst[0].get("s").and_then(Json::as_str), Some("t"));
        assert!(inst[0].get("args").is_some());
    }

    #[test]
    fn spans_emit_matched_pairs() {
        let _g = GATE.lock().unwrap_or_else(|p| p.into_inner());
        let _ = take_events_json(); // start from an empty buffer
        enable(None);
        {
            let _outer = span("unit-outer");
            let _inner = span_args("unit-inner", vec![("k".to_string(), Json::from(7u64))]);
        }
        disable();
        let doc = take_events_json();
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let n_b = evs
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("B"))
            .count();
        let n_e = evs
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("E"))
            .count();
        assert_eq!(n_b, 2);
        assert_eq!(n_e, 2);
        // Monotonic timestamps after the stable sort.
        let mut last = 0.0;
        for e in evs {
            let ts = e.get("ts").and_then(Json::as_f64).unwrap();
            assert!(ts >= last, "ts went backwards: {ts} < {last}");
            last = ts;
        }
    }
}
