//! Lock-cheap, thread-safe metrics registry.
//!
//! Three metric kinds, all backed by atomics so hot paths never block:
//!
//! * [`Counter`] — monotonically increasing `u64` (events, retries,
//!   cache hits, injected faults, ...).
//! * [`Gauge`] — last-write-wins `f64` (thread count, scale, ...).
//! * [`Histogram`] — log2-bucketed `u64` value distribution with exact
//!   count/sum/min/max (span durations in µs, fixed-point iteration
//!   counts, replay throughput, ...).
//!
//! The registry itself is a name → metric map behind a `Mutex`; the lock
//! is taken only on lookup/registration, never while a value is updated.
//! Metrics are leaked (`&'static`) so call sites can cache the reference
//! and update it with a single relaxed atomic op.
//!
//! [`snapshot`] serializes the whole registry to JSON (this is what the
//! sweep checkpoint persists and `damov report telemetry` renders);
//! [`absorb`] merges a previously persisted snapshot back in, which is
//! how a `--resume` run reports cumulative rather than per-run counts.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Monotonically increasing event counter.
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    fn new() -> Counter {
        Counter {
            value: AtomicU64::new(0),
        }
    }

    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn incr(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Last-write-wins f64 value (stored as bits in an atomic).
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    fn new() -> Gauge {
        Gauge {
            bits: AtomicU64::new(0f64.to_bits()),
        }
    }

    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Number of log2 buckets: bucket `b` holds values in
/// `[2^(b-1), 2^b - 1]` (bucket 0 holds exactly 0).
pub const HIST_BUCKETS: usize = 64;

/// Concurrent log2-bucketed histogram over `u64` values.
pub struct Histogram {
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
    buckets: [AtomicU64; HIST_BUCKETS],
}

fn bucket_of(v: u64) -> usize {
    ((64 - v.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
}

/// Upper bound of a bucket's value range (used as the percentile
/// estimate — conservative, at most 2x the true value).
fn bucket_upper(b: usize) -> u64 {
    if b == 0 {
        0
    } else if b >= 63 {
        u64::MAX
    } else {
        (1u64 << b) - 1
    }
}

impl Histogram {
    fn new() -> Histogram {
        Histogram {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    pub fn record(&self, v: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn min(&self) -> u64 {
        let m = self.min.load(Ordering::Relaxed);
        if m == u64::MAX && self.count() == 0 {
            0
        } else {
            m
        }
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum() as f64 / c as f64
        }
    }

    /// Approximate percentile (`q` in [0,1]): upper bound of the bucket
    /// containing the q-th ranked sample.
    pub fn percentile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (b, c) in self.buckets.iter().enumerate() {
            seen += c.load(Ordering::Relaxed);
            if seen >= rank {
                return bucket_upper(b).min(self.max());
            }
        }
        self.max()
    }
}

enum MetricRef {
    Counter(&'static Counter),
    Gauge(&'static Gauge),
    Histogram(&'static Histogram),
}

fn registry() -> &'static Mutex<BTreeMap<String, MetricRef>> {
    static R: OnceLock<Mutex<BTreeMap<String, MetricRef>>> = OnceLock::new();
    R.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Registry lock, recovering from poisoning: the map is only mutated by
/// completed insertions, so it is consistent even after a panic (e.g. a
/// kind-mismatch panic unwinding through a lookup).
fn reg_lock() -> std::sync::MutexGuard<'static, BTreeMap<String, MetricRef>> {
    registry().lock().unwrap_or_else(|p| p.into_inner())
}

/// Look up (or register) the counter with this name.
/// Panics if the name is already registered as a different kind.
pub fn counter(name: &str) -> &'static Counter {
    let mut r = reg_lock();
    let entry = r
        .entry(name.to_string())
        .or_insert_with(|| MetricRef::Counter(Box::leak(Box::new(Counter::new()))));
    match entry {
        MetricRef::Counter(c) => c,
        _ => panic!("metric {name:?} is not a counter"),
    }
}

/// Look up (or register) the gauge with this name.
pub fn gauge(name: &str) -> &'static Gauge {
    let mut r = reg_lock();
    let entry = r
        .entry(name.to_string())
        .or_insert_with(|| MetricRef::Gauge(Box::leak(Box::new(Gauge::new()))));
    match entry {
        MetricRef::Gauge(g) => g,
        _ => panic!("metric {name:?} is not a gauge"),
    }
}

/// Look up (or register) the histogram with this name.
pub fn histogram(name: &str) -> &'static Histogram {
    let mut r = reg_lock();
    let entry = r
        .entry(name.to_string())
        .or_insert_with(|| MetricRef::Histogram(Box::leak(Box::new(Histogram::new()))));
    match entry {
        MetricRef::Histogram(h) => h,
        _ => panic!("metric {name:?} is not a histogram"),
    }
}

/// Serialize every registered metric:
/// `{"counters": {...}, "gauges": {...}, "histograms": {...}}`.
/// Histograms keep their full bucket vector so [`absorb`] is lossless.
pub fn snapshot() -> Json {
    let r = reg_lock();
    let mut counters = Json::obj();
    let mut gauges = Json::obj();
    let mut hists = Json::obj();
    for (name, m) in r.iter() {
        match m {
            MetricRef::Counter(c) => {
                counters.set(name, c.get());
            }
            MetricRef::Gauge(g) => {
                gauges.set(name, g.get());
            }
            MetricRef::Histogram(h) => {
                let mut jh = Json::obj();
                let buckets: Vec<f64> = h
                    .buckets
                    .iter()
                    .map(|b| b.load(Ordering::Relaxed) as f64)
                    .collect();
                jh.set("count", h.count())
                    .set("sum", h.sum())
                    .set("min", h.min())
                    .set("max", h.max())
                    .set("buckets", buckets);
                hists.set(name, jh);
            }
        }
    }
    let mut root = Json::obj();
    root.set("counters", counters)
        .set("gauges", gauges)
        .set("histograms", hists);
    root
}

/// Merge a previously persisted [`snapshot`] into the live registry:
/// counters and histogram contents are added, gauges are overwritten.
/// Used by `--resume` so a recovered sweep reports cumulative counts.
pub fn absorb(snap: &Json) {
    if let Some(Json::Obj(m)) = snap.get("counters") {
        for (name, v) in m.iter() {
            if let Some(x) = v.as_f64() {
                counter(name).add(x as u64);
            }
        }
    }
    if let Some(Json::Obj(m)) = snap.get("gauges") {
        for (name, v) in m.iter() {
            if let Some(x) = v.as_f64() {
                gauge(name).set(x);
            }
        }
    }
    if let Some(Json::Obj(m)) = snap.get("histograms") {
        for (name, jh) in m.iter() {
            let h = histogram(name);
            let count = jh.get("count").and_then(Json::as_f64).unwrap_or(0.0) as u64;
            if count == 0 {
                continue;
            }
            let sum = jh.get("sum").and_then(Json::as_f64).unwrap_or(0.0) as u64;
            let min = jh.get("min").and_then(Json::as_f64).unwrap_or(0.0) as u64;
            let max = jh.get("max").and_then(Json::as_f64).unwrap_or(0.0) as u64;
            h.count.fetch_add(count, Ordering::Relaxed);
            h.sum.fetch_add(sum, Ordering::Relaxed);
            h.min.fetch_min(min, Ordering::Relaxed);
            h.max.fetch_max(max, Ordering::Relaxed);
            if let Some(buckets) = jh.get("buckets").and_then(Json::as_arr) {
                for (b, v) in buckets.iter().enumerate().take(HIST_BUCKETS) {
                    if let Some(x) = v.as_f64() {
                        h.buckets[b].fetch_add(x as u64, Ordering::Relaxed);
                    }
                }
            }
        }
    }
}

/// Human-readable rendering of the current registry (the body of
/// `damov report telemetry`).
pub fn render_text() -> String {
    let r = reg_lock();
    let mut out = String::new();
    let mut counters = Vec::new();
    let mut gauges = Vec::new();
    let mut hists = Vec::new();
    for (name, m) in r.iter() {
        match m {
            MetricRef::Counter(c) => counters.push((name.clone(), c.get())),
            MetricRef::Gauge(g) => gauges.push((name.clone(), g.get())),
            MetricRef::Histogram(h) => hists.push((name.clone(), *h)),
        }
    }
    if counters.is_empty() && gauges.is_empty() && hists.is_empty() {
        out.push_str("(no metrics recorded)\n");
        return out;
    }
    if !counters.is_empty() {
        out.push_str("counters:\n");
        for (name, v) in &counters {
            out.push_str(&format!("  {name:<36} {v}\n"));
        }
    }
    if !gauges.is_empty() {
        out.push_str("gauges:\n");
        for (name, v) in &gauges {
            out.push_str(&format!("  {name:<36} {v}\n"));
        }
    }
    if !hists.is_empty() {
        out.push_str(&format!(
            "histograms:{:<27} {:>10} {:>14} {:>10} {:>10} {:>10} {:>10}\n",
            "", "count", "mean", "min", "p50", "p99", "max"
        ));
        for (name, h) in &hists {
            out.push_str(&format!(
                "  {name:<36} {:>10} {:>14.1} {:>10} {:>10} {:>10} {:>10}\n",
                h.count(),
                h.mean(),
                h.min(),
                h.percentile(0.50),
                h.percentile(0.99),
                h.max()
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = counter("unit.metrics.counter");
        let before = c.get();
        c.incr();
        c.add(4);
        assert_eq!(c.get(), before + 5);
        // Same name returns the same cell.
        assert_eq!(counter("unit.metrics.counter").get(), before + 5);
    }

    #[test]
    fn gauge_last_write_wins() {
        let g = gauge("unit.metrics.gauge");
        g.set(2.5);
        g.set(7.25);
        assert_eq!(g.get(), 7.25);
    }

    #[test]
    fn histogram_stats_and_percentiles() {
        let h = histogram("unit.metrics.hist");
        for v in 1..=100u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 100);
        assert_eq!(h.sum(), 5050);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        // Log2 buckets: estimates are upper bounds, within 2x.
        let p50 = h.percentile(0.5);
        assert!((50..=127).contains(&p50), "p50={p50}");
        assert_eq!(h.percentile(1.0), 100);
    }

    #[test]
    fn bucket_edges() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn snapshot_contains_registered_metrics() {
        counter("unit.metrics.snap_counter").add(3);
        histogram("unit.metrics.snap_hist").record(10);
        let snap = snapshot();
        let c = snap
            .get("counters")
            .and_then(|m| m.get("unit.metrics.snap_counter"))
            .and_then(Json::as_f64)
            .unwrap();
        assert!(c >= 3.0);
        let hc = snap
            .get("histograms")
            .and_then(|m| m.get("unit.metrics.snap_hist"))
            .and_then(|h| h.get("count"))
            .and_then(Json::as_f64)
            .unwrap();
        assert!(hc >= 1.0);
    }

    #[test]
    fn absorb_adds_counters_and_histograms() {
        // Hand-built snapshot naming only this test's metrics, so
        // absorbing it cannot interfere with concurrently running tests.
        let mut counters = Json::obj();
        counters.set("unit.metrics.absorb_counter", 5u64);
        let mut jh = Json::obj();
        let mut buckets = vec![0.0f64; HIST_BUCKETS];
        buckets[bucket_of(12)] = 2.0;
        jh.set("count", 2u64)
            .set("sum", 24u64)
            .set("min", 12u64)
            .set("max", 12u64)
            .set("buckets", buckets);
        let mut hists = Json::obj();
        hists.set("unit.metrics.absorb_hist", jh);
        let mut snap = Json::obj();
        snap.set("counters", counters)
            .set("gauges", Json::obj())
            .set("histograms", hists);

        let c = counter("unit.metrics.absorb_counter");
        let h = histogram("unit.metrics.absorb_hist");
        let c_before = c.get();
        let h_count_before = h.count();
        let h_sum_before = h.sum();
        absorb(&snap);
        assert_eq!(c.get(), c_before + 5);
        assert_eq!(h.count(), h_count_before + 2);
        assert_eq!(h.sum(), h_sum_before + 24);
        assert_eq!(h.min(), 12);
    }

    #[test]
    fn registered_kind_is_sticky() {
        let _ = counter("unit.metrics.sticky");
        let r = std::panic::catch_unwind(|| gauge("unit.metrics.sticky"));
        assert!(r.is_err(), "same name as a different kind must panic");
    }
}
