//! Plain-text table rendering for the report harness (`damov report ...`).
//! Every paper table/figure is regenerated as an aligned text table plus a
//! JSON sidecar; this module does the text half.

/// Column-aligned text table with a header row.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, cell) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                let pad = widths[i] - cell.chars().count();
                // Right-align numeric-looking cells, left-align text.
                let numeric = cell
                    .chars()
                    .all(|c| c.is_ascii_digit() || matches!(c, '.' | '-' | '+' | 'e' | 'x' | '%'))
                    && cell.chars().any(|c| c.is_ascii_digit());
                if numeric {
                    line.push_str(&" ".repeat(pad));
                    line.push_str(cell);
                } else {
                    line.push_str(cell);
                    line.push_str(&" ".repeat(pad));
                }
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Format a float with sensible width for report cells.
pub fn f(x: f64) -> String {
    if !x.is_finite() {
        return "-".to_string();
    }
    if x == 0.0 {
        return "0".to_string();
    }
    let a = x.abs();
    if a >= 1000.0 {
        format!("{x:.0}")
    } else if a >= 10.0 {
        format!("{x:.1}")
    } else if a >= 0.01 {
        format!("{x:.3}")
    } else {
        format!("{x:.2e}")
    }
}

/// Render a horizontal ASCII bar of `frac` (0..=1) with the given width.
pub fn bar(frac: f64, width: usize) -> String {
    let frac = frac.clamp(0.0, 1.0);
    let filled = (frac * width as f64).round() as usize;
    let mut s = String::with_capacity(width);
    for i in 0..width {
        s.push(if i < filled { '#' } else { '.' });
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "mpki"]);
        t.row(vec!["STRTriad".into(), "47.2".into()]);
        t.row(vec!["x".into(), "1.5".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        let lines: Vec<&str> = s.lines().collect();
        // header + rule + 2 rows
        assert_eq!(lines.len(), 5);
        // numeric column right-aligned: both rows end at same column
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn float_formatting() {
        assert_eq!(f(0.0), "0");
        assert_eq!(f(12345.0), "12345");
        assert_eq!(f(47.25), "47.2");
        assert_eq!(f(1.5), "1.500");
        assert_eq!(f(f64::NAN), "-");
        assert!(f(0.0001).contains('e'));
    }

    #[test]
    fn bars() {
        assert_eq!(bar(0.5, 10), "#####.....");
        assert_eq!(bar(-1.0, 4), "....");
        assert_eq!(bar(2.0, 4), "####");
    }
}
