//! Infrastructure substrates built in-repo (the offline environment ships
//! only the `xla` crate closure — no serde/clap/rayon/criterion/proptest).

pub mod cancel;
pub mod cli;
pub mod fault;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
pub mod telemetry;
