//! Tiny command-line parser (no `clap` in the offline crate set).
//!
//! Supports the shapes the `damov` binary needs:
//! `damov <command> [positional...] [--flag] [--key value | --key=value]`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw argv (excluding argv[0]). `known_flags` lists boolean
    /// switches; every other `--key` consumes the next token as its value
    /// (or uses the `=`-suffix form).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I, known_flags: &[&str]) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&stripped) {
                    out.flags.push(stripped.to_string());
                } else if let Some(next) = iter.peek() {
                    if next.starts_with("--") {
                        out.flags.push(stripped.to_string());
                    } else {
                        out.options.insert(stripped.to_string(), iter.next().unwrap());
                    }
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> usize {
        self.opt(name)
            .map(|s| {
                s.parse::<usize>()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got {s:?}"))
            })
            .unwrap_or(default)
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> u64 {
        self.opt(name)
            .map(|s| {
                s.parse::<u64>()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got {s:?}"))
            })
            .unwrap_or(default)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name)
            .map(|s| {
                s.parse::<f64>()
                    .unwrap_or_else(|_| panic!("--{name} expects a number, got {s:?}"))
            })
            .unwrap_or(default)
    }

    /// Comma-separated usize list, e.g. `--cores 1,4,16`.
    pub fn opt_usize_list(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.opt(name) {
            None => default.to_vec(),
            Some(s) => s
                .split(',')
                .filter(|t| !t.is_empty())
                .map(|t| {
                    t.trim()
                        .parse::<usize>()
                        .unwrap_or_else(|_| panic!("--{name}: bad integer {t:?}"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn command_and_positionals() {
        let a = Args::parse(argv("report fig5 fig6"), &[]);
        assert_eq!(a.command.as_deref(), Some("report"));
        assert_eq!(a.positional, vec!["fig5", "fig6"]);
    }

    #[test]
    fn options_both_forms() {
        let a = Args::parse(argv("sim --cores 64 --system=ndp"), &[]);
        assert_eq!(a.opt("cores"), Some("64"));
        assert_eq!(a.opt("system"), Some("ndp"));
    }

    #[test]
    fn known_flags_do_not_consume() {
        let a = Args::parse(argv("sim --verbose tracefile"), &["verbose"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["tracefile"]);
    }

    #[test]
    fn unknown_flag_before_option_is_flag() {
        let a = Args::parse(argv("x --fast --k v"), &[]);
        assert!(a.flag("fast"));
        assert_eq!(a.opt("k"), Some("v"));
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(argv("x --inorder"), &[]);
        assert!(a.flag("inorder"));
    }

    #[test]
    fn usize_list() {
        let a = Args::parse(argv("x --cores 1,4,16,64"), &[]);
        assert_eq!(a.opt_usize_list("cores", &[]), vec![1, 4, 16, 64]);
        assert_eq!(a.opt_usize_list("missing", &[2, 3]), vec![2, 3]);
    }

    #[test]
    fn typed_defaults() {
        let a = Args::parse(argv("x"), &[]);
        assert_eq!(a.opt_usize("n", 7), 7);
        assert_eq!(a.opt_f64("p", 0.5), 0.5);
    }
}
