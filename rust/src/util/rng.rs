//! Deterministic PRNGs for workload trace generation.
//!
//! The offline environment has no `rand` crate, so we implement the two
//! generators every workload generator in this repo depends on:
//! [`SplitMix64`] for seeding and [`Xoshiro256`] (xoshiro256**) as the
//! general-purpose stream. Determinism is a hard requirement: each DAMOV
//! workload function must produce an identical memory trace for a given
//! seed so that experiments are reproducible across runs and machines.

/// SplitMix64: tiny, fast seeder (Steele et al.). Used to expand one u64
/// seed into the 256-bit xoshiro state and for cheap one-off hashing.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// One-shot integer hash (stateless SplitMix64 step). Handy for hash-join
/// and histogram workloads that need a well-mixed hash function.
#[inline]
pub fn mix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** 1.0 (Blackman & Vigna): the repo's general-purpose PRNG.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)`. Uses Lemire's multiply-shift reduction;
    /// bias is negligible for the bounds used here (all < 2^40).
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn gen_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Approximately Zipf-distributed index in `[0, n)` with exponent `s`,
    /// via inverse-CDF on the harmonic approximation. Used by graph and
    /// key-skew workloads.
    pub fn gen_zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        if s <= 0.0 {
            return self.gen_usize(0, n);
        }
        let u = self.gen_f64().max(1e-12);
        if (s - 1.0).abs() < 1e-9 {
            let hn = (n as f64).ln() + 0.5772156649;
            let x = (u * hn).exp_m1() + 1.0; // e^{u*H_n} ~ rank
            return (x.min(n as f64) as usize).saturating_sub(1).min(n - 1);
        }
        let one_minus_s = 1.0 - s;
        let hn = ((n as f64).powf(one_minus_s) - 1.0) / one_minus_s;
        let x = (u * hn * one_minus_s + 1.0).powf(1.0 / one_minus_s);
        (x.min(n as f64) as usize).saturating_sub(1).min(n - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference values from the public-domain splitmix64.c with seed
        // 1234567: first three outputs.
        let mut sm = SplitMix64::new(1234567);
        assert_eq!(sm.next_u64(), 6457827717110365317);
        assert_eq!(sm.next_u64(), 3203168211198807973);
        assert_eq!(sm.next_u64(), 9817491932198370423);
    }

    #[test]
    fn xoshiro_deterministic_and_distinct() {
        let mut a = Xoshiro256::new(42);
        let mut b = Xoshiro256::new(42);
        let mut c = Xoshiro256::new(43);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Xoshiro256::new(7);
        for _ in 0..10_000 {
            let v = r.gen_range(17);
            assert!(v < 17);
        }
    }

    #[test]
    fn gen_f64_unit_interval_and_rough_mean() {
        let mut r = Xoshiro256::new(9);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_skews_low_ranks() {
        let mut r = Xoshiro256::new(11);
        let n = 1000;
        let mut low = 0;
        for _ in 0..10_000 {
            if r.gen_zipf(n, 1.0) < 10 {
                low += 1;
            }
        }
        // Zipf(1.0): P(rank<10) ~ H_10/H_1000 ~ 0.39. Uniform would be 1%.
        assert!(low > 2000, "low-rank draws = {low}");
    }

    #[test]
    fn zipf_zero_exponent_is_uniform_range() {
        let mut r = Xoshiro256::new(13);
        for _ in 0..1000 {
            assert!(r.gen_zipf(50, 0.0) < 50);
        }
    }
}
