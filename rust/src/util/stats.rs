//! Small statistics helpers used by reports (box plots in Fig 18, means,
//! percentiles) and by the clustering code.

/// Five-number summary + mean, matching the paper's Fig 18 box plots
/// (quartile box, median, min/max whiskers).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub min: f64,
    pub q1: f64,
    pub median: f64,
    pub q3: f64,
    pub max: f64,
    pub mean: f64,
    pub n: usize,
}

impl Summary {
    pub fn of(values: &[f64]) -> Option<Summary> {
        let mut v: Vec<f64> = values.iter().copied().filter(|x| x.is_finite()).collect();
        if v.is_empty() {
            return None;
        }
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = v.iter().sum::<f64>() / v.len() as f64;
        Some(Summary {
            min: v[0],
            q1: percentile_sorted(&v, 25.0),
            median: percentile_sorted(&v, 50.0),
            q3: percentile_sorted(&v, 75.0),
            max: v[v.len() - 1],
            mean,
            n: v.len(),
        })
    }

    /// One-line rendering for text reports: `min [q1 | med | q3] max (mean)`.
    pub fn render(&self) -> String {
        format!(
            "{:8.3} [{:8.3} |{:8.3} |{:8.3} ]{:9.3}  mean={:8.3} n={}",
            self.min, self.q1, self.median, self.q3, self.max, self.mean, self.n
        )
    }
}

/// Linear-interpolated percentile of an already-sorted slice, p in [0,100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

pub fn mean(values: &[f64]) -> f64 {
    if values.is_empty() {
        return f64::NAN;
    }
    values.iter().sum::<f64>() / values.len() as f64
}

/// Geometric mean (used for cross-workload speedup aggregation).
pub fn geomean(values: &[f64]) -> f64 {
    if values.is_empty() {
        return f64::NAN;
    }
    let log_sum: f64 = values.iter().map(|v| v.max(1e-300).ln()).sum();
    (log_sum / values.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(values: &[f64]) -> f64 {
    if values.len() < 2 {
        return 0.0;
    }
    let m = mean(values);
    (values.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / values.len() as f64).sqrt()
}

/// Euclidean distance between feature vectors (clustering).
pub fn euclidean(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .sqrt()
}

/// Min-max normalize each column of a row-major feature matrix in place so
/// every feature contributes comparably to clustering distances.
pub fn normalize_columns(rows: &mut [Vec<f64>]) {
    if rows.is_empty() {
        return;
    }
    let dims = rows[0].len();
    for d in 0..dims {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for r in rows.iter() {
            lo = lo.min(r[d]);
            hi = hi.max(r[d]);
        }
        let span = (hi - lo).max(1e-12);
        for r in rows.iter_mut() {
            r[d] = (r[d] - lo) / span;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.min, 1.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.q1, 2.0);
        assert_eq!(s.q3, 4.0);
    }

    #[test]
    fn summary_filters_nonfinite() {
        let s = Summary::of(&[1.0, f64::NAN, 3.0]).unwrap();
        assert_eq!(s.n, 2);
        assert_eq!(s.mean, 2.0);
        assert!(Summary::of(&[f64::NAN]).is_none());
    }

    #[test]
    fn percentile_interpolates() {
        let v = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile_sorted(&v, 0.0), 10.0);
        assert_eq!(percentile_sorted(&v, 100.0), 40.0);
        assert!((percentile_sorted(&v, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn euclid() {
        assert!((euclidean(&[0.0, 0.0], &[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn normalize_unit_range() {
        let mut rows = vec![vec![0.0, 10.0], vec![5.0, 20.0], vec![10.0, 30.0]];
        normalize_columns(&mut rows);
        assert_eq!(rows[0], vec![0.0, 0.0]);
        assert_eq!(rows[2], vec![1.0, 1.0]);
        assert_eq!(rows[1], vec![0.5, 0.5]);
    }

    #[test]
    fn stddev_basic() {
        assert!((stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
    }
}
