//! Scoped parallel-map over OS threads (no `rayon`/`tokio` offline).
//!
//! The experiment coordinator fans hundreds of independent simulations out
//! across cores; each job is CPU-bound and seconds-long, so a simple
//! work-stealing-free chunked scheduler with an atomic cursor is plenty.
//!
//! [`par_map_catch_opts`] adds deadline awareness on top of the panic
//! isolation of [`par_map_catch`]: a per-job wall-clock budget
//! (`--job-timeout`), a sweep-wide budget (`--sweep-deadline`), and a
//! watchdog thread that scans per-worker job start stamps and
//! soft-cancels overdue jobs through their [`cancel::CancelToken`]. A
//! cancelled job exits by unwinding at its next [`cancel::poll`] point,
//! so its (partial) result is discarded, never half-written; the slot is
//! recorded as a [`JobError`] with [`JobErrorKind::TimedOut`] or
//! [`JobErrorKind::Cancelled`].

use crate::util::cancel::{self, CancelReason, CancelToken, Deadline};
use crate::util::json::Json;
use crate::util::telemetry::{self, metrics, trace};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Number of worker threads to use: the `DAMOV_THREADS` env var if set,
/// otherwise available parallelism (min 1). An unparseable value is
/// reported (a misconfigured sweep should be visible, not silent) and
/// treated as unset.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("DAMOV_THREADS") {
        match v.trim().parse::<usize>() {
            Ok(n) => return n.max(1),
            Err(e) => {
                telemetry::warn(
                    "config",
                    &[(
                        "detail",
                        Json::from(format!(
                            "ignoring unparseable DAMOV_THREADS={v:?} ({e}); \
                             falling back to available parallelism"
                        )),
                    )],
                );
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

// ---------------------------------------------------------------------
// Global thread budget — the nested-parallelism rule.
// ---------------------------------------------------------------------
//
// Two parallel levels run at once in a sweep: the outer function fan-out
// (`par_map_catch_opts`) and the inner per-trace config-point fan-out
// (`par_map_extra` in `methodology::step3`). Left unguarded they would
// multiply into `outer × inner` OS threads. Instead, every *spawned*
// worker thread is registered against one process-global budget of
// [`budget_total`] lanes: outer pools register unconditionally (the
// level the user sized with `--threads` always gets what it asked for),
// while inner levels borrow opportunistically via [`budget_acquire`] and
// degrade to serial-on-the-calling-thread when nothing is spare. The
// calling thread itself is never counted — blocked callers cost nothing,
// and a caller participating in its own inner map is an already-counted
// (or top-level) thread. See `docs/performance.md`.

/// Spawned worker threads currently registered against the budget.
static BUDGET_IN_USE: AtomicUsize = AtomicUsize::new(0);

/// Size of the global thread budget: [`default_threads`] (i.e.
/// `DAMOV_THREADS` or available parallelism).
pub fn budget_total() -> usize {
    default_threads()
}

/// Worker threads currently drawn from the budget (outer pool workers
/// plus borrowed inner lanes). Observability hook.
pub fn budget_in_use() -> usize {
    BUDGET_IN_USE.load(Ordering::Acquire)
}

/// RAII registration of worker threads against the global budget.
pub struct BudgetLease {
    n: usize,
}

impl BudgetLease {
    /// How many *extra* worker threads this lease grants. The calling
    /// thread always keeps its own lane on top of this.
    pub fn extra(&self) -> usize {
        self.n
    }
}

impl Drop for BudgetLease {
    fn drop(&mut self) {
        if self.n > 0 {
            BUDGET_IN_USE.fetch_sub(self.n, Ordering::AcqRel);
            metrics::gauge("pool.budget_in_use").set(budget_in_use() as f64);
        }
    }
}

/// Unconditionally register `n` spawned workers (an outer pool claiming
/// the threads the user asked for). May oversubscribe the machine if
/// `--threads` exceeds the budget; only opportunistic inner levels
/// degrade, never the explicit outer request.
fn budget_charge(n: usize) -> BudgetLease {
    BUDGET_IN_USE.fetch_add(n, Ordering::AcqRel);
    metrics::gauge("pool.budget_in_use").set(budget_in_use() as f64);
    BudgetLease { n }
}

/// Borrow up to `want` extra worker threads from whatever the budget has
/// to spare. Never blocks and never fails: with the budget exhausted the
/// lease grants 0 extra lanes and the caller runs serially on its own
/// thread, so nested parallelism can never deadlock or multiply levels.
pub fn budget_acquire(want: usize) -> BudgetLease {
    let total = budget_total();
    loop {
        let used = BUDGET_IN_USE.load(Ordering::Acquire);
        let take = want.min(total.saturating_sub(used));
        if take == 0 {
            return BudgetLease { n: 0 };
        }
        if BUDGET_IN_USE
            .compare_exchange(used, used + take, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
        {
            metrics::gauge("pool.budget_in_use").set(budget_in_use() as f64);
            return BudgetLease { n: take };
        }
    }
}

/// Parallel map on the *calling* thread plus up to `extra` borrowed
/// worker threads (typically granted by [`budget_acquire`]). Unlike
/// [`par_map`], the caller participates in the work, so `extra = 0`
/// degrades to a plain serial map with zero thread overhead — the shape
/// the inner config-point fan-out needs when outer sweep workers hold
/// the whole budget.
///
/// The caller's installed [`CancelToken`] (if any) is propagated to the
/// borrowed workers, so a watchdog soft-cancel of the outer job reaches
/// nested replays at their next [`cancel::poll`]. A panic on any lane
/// (including a cancellation unwind) aborts the map — remaining items
/// are skipped — and is re-raised on the calling thread with its
/// original payload, preserving [`cancel::CANCEL_MARKER`] semantics for
/// the outer `run_caught` boundary. Result order matches input order.
pub fn par_map_extra<T, R, F>(items: &[T], extra: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    let extra = extra.min(n.saturating_sub(1));
    if extra == 0 {
        return items.iter().map(|t| f(t)).collect();
    }

    let cursor = AtomicUsize::new(0);
    let abort = AtomicBool::new(false);
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let first_panic: Mutex<Option<Box<dyn std::any::Any + Send>>> = Mutex::new(None);
    let token = cancel::current();

    // Shared lane body. Per-item catch_unwind (rather than letting the
    // scope propagate) keeps the original panic payload: std's scope
    // replaces a child's payload with a generic message, which would
    // erase the cancellation marker.
    let work = |tok: Option<CancelToken>| {
        let _guard = tok.map(cancel::install);
        loop {
            if abort.load(Ordering::Relaxed) {
                break;
            }
            let i = cursor.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            match catch_unwind(AssertUnwindSafe(|| f(&items[i]))) {
                Ok(r) => *results[i].lock().unwrap() = Some(r),
                Err(payload) => {
                    abort.store(true, Ordering::Relaxed);
                    let mut slot = first_panic.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(payload);
                    }
                    break;
                }
            }
        }
    };

    std::thread::scope(|scope| {
        let work = &work;
        for _ in 0..extra {
            let tok = token.clone();
            scope.spawn(move || work(tok));
        }
        // The calling thread participates; its token (if any) is already
        // installed thread-locally.
        work(None);
    });

    if let Some(payload) = first_panic.into_inner().unwrap_or_else(|p| p.into_inner()) {
        std::panic::resume_unwind(payload);
    }
    results
        .into_iter()
        .enumerate()
        .map(|(i, m)| {
            m.into_inner()
                .unwrap_or_else(|p| p.into_inner())
                .unwrap_or_else(|| unreachable!("par_map_extra job {i}/{n} missing result"))
        })
        .collect()
}

/// Apply `f` to every item of `items` in parallel, preserving order of
/// results. `f` must be `Sync` (called concurrently from many threads).
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return items.iter().map(|t| f(t)).collect();
    }

    let cursor = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();

    let _budget = budget_charge(threads);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });

    results
        .into_iter()
        .enumerate()
        .map(|(i, m)| {
            m.into_inner()
                .unwrap_or_else(|p| p.into_inner())
                .unwrap_or_else(|| panic!("worker panicked while running job {i}/{n}"))
        })
        .collect()
}

/// How a job ultimately failed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobErrorKind {
    /// The job body panicked on every attempt.
    Panicked,
    /// The job exceeded `--job-timeout` and was soft-cancelled by the
    /// watchdog. Never retried in-sweep; recorded as retryable so
    /// `--resume` re-runs it.
    TimedOut,
    /// The job was cancelled by a sweep-wide deadline or shutdown
    /// (possibly before it ever started).
    Cancelled,
}

impl JobErrorKind {
    /// Stable lowercase label used in telemetry and checkpoint records.
    pub fn label(&self) -> &'static str {
        match self {
            JobErrorKind::Panicked => "panicked",
            JobErrorKind::TimedOut => "timed-out",
            JobErrorKind::Cancelled => "cancelled",
        }
    }
}

/// A job that did not produce a value: panicked on every attempt, timed
/// out, or was cancelled.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobError {
    /// Index of the failed item in the input slice.
    pub index: usize,
    /// Number of attempts made (1 + retries; 0 for jobs cancelled
    /// before they started).
    pub attempts: u32,
    /// What happened on the last attempt.
    pub kind: JobErrorKind,
    /// Panic payload of the last attempt, stringified.
    pub message: String,
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "job {} {} after {} attempt(s): {}",
            self.index,
            self.kind.label(),
            self.attempts,
            self.message
        )
    }
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Backoff before retry `attempt` (1-based): exponential starting at
/// 5 ms, capped at 200 ms — 5, 10, 20, 40, 80, 160, 200, 200, ...
fn retry_backoff_ms(attempt: u32) -> u64 {
    (5u64 << attempt.saturating_sub(1).min(6)).min(200)
}

/// Run one job with panic isolation and bounded retry. Backoff is
/// exponential starting at 5 ms (see [`retry_backoff_ms`]), capped at
/// 200 ms — transient faults (I/O pressure, injected panics) clear
/// quickly; deterministic bugs fail fast with their identity attached.
/// A cancellation unwind (payload carrying [`cancel::CANCEL_MARKER`])
/// is not a failure of the job body: it maps to `TimedOut`/`Cancelled`
/// per the token's reason and is never retried.
fn run_caught<T, R, F>(
    items: &[T],
    i: usize,
    max_retries: u32,
    token: Option<&CancelToken>,
    f: &F,
) -> Result<R, JobError>
where
    T: Sync,
    F: Fn(&T) -> R + Sync,
{
    metrics::counter("pool.jobs").incr();
    let mut attempt = 0u32;
    loop {
        // Span guard lives outside the unwind boundary so its E event
        // fires even when the job panics.
        let span = trace::span_args("job", vec![("job".to_string(), Json::from(i as u64))]);
        let caught = catch_unwind(AssertUnwindSafe(|| f(&items[i])));
        drop(span);
        match caught {
            Ok(r) => return Ok(r),
            Err(payload) => {
                let message = panic_message(payload);
                if message.contains(cancel::CANCEL_MARKER) {
                    let reason = token
                        .and_then(|t| t.reason())
                        .unwrap_or(CancelReason::Shutdown);
                    let kind = match reason {
                        CancelReason::JobTimeout => JobErrorKind::TimedOut,
                        _ => JobErrorKind::Cancelled,
                    };
                    telemetry::warn(
                        "job-cancelled",
                        &[
                            ("site", Json::from("pool")),
                            ("job", Json::from(i as u64)),
                            ("attempt", Json::from((attempt + 1) as u64)),
                            ("reason", Json::from(reason.label())),
                        ],
                    );
                    return Err(JobError {
                        index: i,
                        attempts: attempt + 1,
                        kind,
                        message,
                    });
                }
                metrics::counter("pool.panics").incr();
                // A cancelled job must not burn wall-clock on retries.
                let cancelled = token.map(|t| t.is_cancelled()).unwrap_or(false);
                if attempt >= max_retries || cancelled {
                    metrics::counter("pool.failures").incr();
                    return Err(JobError {
                        index: i,
                        attempts: attempt + 1,
                        kind: JobErrorKind::Panicked,
                        message,
                    });
                }
                attempt += 1;
                metrics::counter("pool.retries").incr();
                let backoff = retry_backoff_ms(attempt);
                telemetry::warn(
                    "retry",
                    &[
                        ("site", Json::from("pool")),
                        ("job", Json::from(i as u64)),
                        ("attempt", Json::from(attempt as u64)),
                        ("backoff_ms", Json::from(backoff)),
                        ("error", Json::from(message)),
                    ],
                );
                std::thread::sleep(Duration::from_millis(backoff));
            }
        }
    }
}

/// Scheduling knobs for [`par_map_catch_opts`].
#[derive(Debug, Clone, Copy, Default)]
pub struct PoolOptions {
    /// Worker threads (clamped to `1..=items.len()`).
    pub threads: usize,
    /// Retries per panicking job before it is recorded as failed.
    pub max_retries: u32,
    /// Per-job wall-clock budget: an overdue job is soft-cancelled by
    /// the watchdog and recorded as `TimedOut`. `None` = unbounded.
    pub job_timeout: Option<Duration>,
    /// Sweep-wide budget measured from pool entry: on expiry all
    /// in-flight jobs are cancelled and queued jobs are recorded as
    /// `Cancelled` without starting. `None` = unbounded.
    pub sweep_deadline: Option<Duration>,
}

impl PoolOptions {
    /// Options with no deadlines (the classic [`par_map_catch`] shape).
    pub fn new(threads: usize, max_retries: u32) -> PoolOptions {
        PoolOptions {
            threads,
            max_retries,
            job_timeout: None,
            sweep_deadline: None,
        }
    }

    fn bounded(&self) -> bool {
        self.job_timeout.is_some() || self.sweep_deadline.is_some()
    }
}

/// Fallible sibling of [`par_map`]: apply `f` to every item in parallel,
/// catching worker panics instead of aborting the whole map. Each result
/// slot reports either the value or a [`JobError`] naming the failed
/// item, so one bad job costs one record, not the whole sweep. Panicking
/// jobs are retried up to `max_retries` times with exponential backoff
/// before being recorded as failed. Order is preserved.
pub fn par_map_catch<T, R, F>(
    items: &[T],
    threads: usize,
    max_retries: u32,
    f: F,
) -> Vec<Result<R, JobError>>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    par_map_catch_opts(items, &PoolOptions::new(threads, max_retries), f)
}

/// Sentinel job index marking a worker slot as idle.
const IDLE: usize = usize::MAX;

/// Per-worker published state the watchdog scans: which job is
/// in-flight, when it started, and the token to cancel it with.
struct WorkerSlot {
    /// In-flight job index, or [`IDLE`].
    job: AtomicUsize,
    /// Job start stamp, microseconds on the [`trace::now_us`] clock.
    start_us: AtomicU64,
    token: Mutex<Option<CancelToken>>,
    /// Job index + 1 whose grace overrun was already reported, so the
    /// watchdog complains about each stuck job exactly once.
    grace_reported: AtomicUsize,
}

impl WorkerSlot {
    fn new() -> WorkerSlot {
        WorkerSlot {
            job: AtomicUsize::new(IDLE),
            start_us: AtomicU64::new(0),
            token: Mutex::new(None),
            grace_reported: AtomicUsize::new(0),
        }
    }

    /// Publish job `i` as in-flight on this slot.
    fn arm(&self, i: usize, token: CancelToken) {
        *self.token.lock().unwrap() = Some(token);
        self.start_us.store(trace::now_us(), Ordering::Relaxed);
        // Release-publish last: a watchdog that sees the index also
        // sees the stamp and token.
        self.job.store(i, Ordering::Release);
    }

    fn disarm(&self) {
        self.job.store(IDLE, Ordering::Release);
        *self.token.lock().unwrap() = None;
    }
}

/// How long after a soft-cancel the watchdog waits before reporting a
/// job as stuck (i.e. not reaching a [`cancel::poll`] point).
const CANCEL_GRACE: Duration = Duration::from_secs(1);

/// Watchdog loop: every few milliseconds scan the worker slots, cancel
/// overdue jobs, maintain the in-flight job-age gauge, and trip the
/// sweep-wide stop flag when the deadline expires. Exits when all
/// workers have finished.
fn watchdog(
    slots: &[WorkerSlot],
    stop: &AtomicBool,
    live_workers: &AtomicUsize,
    job_timeout: Option<Duration>,
    deadline: Option<Deadline>,
) {
    // Tick fast enough that cancellation latency is dominated by the
    // jobs' own poll interval, not by the watchdog.
    let tick = Duration::from_millis(5);
    let grace_us = CANCEL_GRACE.as_micros() as u64;
    while live_workers.load(Ordering::Acquire) != 0 {
        let now = trace::now_us();
        let deadline_hit = deadline.map(|d| d.expired()).unwrap_or(false);
        if deadline_hit && !stop.swap(true, Ordering::AcqRel) {
            metrics::counter("pool.deadline_hits").incr();
            telemetry::warn(
                "sweep-deadline",
                &[(
                    "detail",
                    Json::from(
                        "sweep deadline reached; cancelling in-flight jobs \
                         and skipping queued ones",
                    ),
                )],
            );
            trace::instant("sweep-deadline", Vec::new());
        }
        let mut max_age_us = 0u64;
        for slot in slots {
            let job = slot.job.load(Ordering::Acquire);
            if job == IDLE {
                continue;
            }
            let start_us = slot.start_us.load(Ordering::Relaxed);
            let age_us = now.saturating_sub(start_us);
            max_age_us = max_age_us.max(age_us);
            let overdue = job_timeout
                .map(|t| age_us as u128 > t.as_micros())
                .unwrap_or(false);
            if !overdue && !deadline_hit {
                continue;
            }
            let token = slot.token.lock().unwrap().clone();
            let Some(token) = token else { continue };
            // The slot may have been disarmed and re-armed with a fresh
            // job between the scan and the clone; only cancel if it still
            // holds the job the age was computed for (a stale cancel on a
            // completed job's token would otherwise hit its successor).
            if slot.job.load(Ordering::Acquire) != job
                || slot.start_us.load(Ordering::Relaxed) != start_us
            {
                continue;
            }
            let reason = if overdue {
                CancelReason::JobTimeout
            } else {
                CancelReason::SweepDeadline
            };
            if token.cancel(reason) {
                match reason {
                    CancelReason::JobTimeout => metrics::counter("pool.timeouts").incr(),
                    _ => metrics::counter("pool.cancels").incr(),
                }
                telemetry::warn(
                    "timeout",
                    &[
                        ("site", Json::from("pool")),
                        ("job", Json::from(job as u64)),
                        ("age_ms", Json::from(age_us / 1000)),
                        ("reason", Json::from(reason.label())),
                    ],
                );
                trace::instant(
                    "cancel",
                    vec![
                        ("job".to_string(), Json::from(job as u64)),
                        ("reason".to_string(), Json::from(reason.label())),
                    ],
                );
            } else {
                // Already cancelled on an earlier tick; if the job still
                // hasn't unwound past the grace period, report it once —
                // it is wedged somewhere without a poll point and its
                // lane stays lost until it reaches one.
                let cancelled_at = token.cancelled_at_us();
                if cancelled_at != 0
                    && now.saturating_sub(cancelled_at) > grace_us
                    && slot.grace_reported.swap(job + 1, Ordering::Relaxed) != job + 1
                {
                    metrics::counter("pool.cancel_stuck").incr();
                    telemetry::error(
                        "cancel-stuck",
                        &[
                            ("job", Json::from(job as u64)),
                            ("age_ms", Json::from(age_us / 1000)),
                            (
                                "detail",
                                Json::from(
                                    "job ignored cancellation past the grace \
                                     period; it has no reachable poll point",
                                ),
                            ),
                        ],
                    );
                }
            }
        }
        metrics::gauge("pool.inflight_age_us").set(max_age_us as f64);
        std::thread::sleep(tick);
    }
    metrics::gauge("pool.inflight_age_us").set(0.0);
}

/// Install (once per process) a panic-hook filter that silences the
/// intentional unwinds used by cooperative cancellation; every other
/// panic goes to the previous hook unchanged.
fn install_cancel_panic_hook() {
    use std::sync::OnceLock;
    static HOOK: OnceLock<()> = OnceLock::new();
    HOOK.get_or_init(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let msg = info
                .payload()
                .downcast_ref::<String>()
                .map(String::as_str)
                .or_else(|| info.payload().downcast_ref::<&str>().copied())
                .unwrap_or("");
            if !msg.contains(cancel::CANCEL_MARKER) {
                prev(info);
            }
        }));
    });
}

/// Deadline-aware sibling of [`par_map_catch`]. With no deadlines in
/// `opts` the behavior (and fast path) is identical; with a job timeout
/// and/or sweep deadline configured, a watchdog thread soft-cancels
/// overdue work via per-job [`CancelToken`]s. Every input slot is still
/// filled: values for completed jobs, `JobError`s (with the failure
/// kind) for everything else, in input order.
pub fn par_map_catch_opts<T, R, F>(
    items: &[T],
    opts: &PoolOptions,
    f: F,
) -> Vec<Result<R, JobError>>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = opts.threads.max(1).min(n);
    let max_retries = opts.max_retries;
    let bounded = opts.bounded();
    if threads == 1 && !bounded {
        return (0..n)
            .map(|i| run_caught(items, i, max_retries, None, &f))
            .collect();
    }
    if bounded {
        install_cancel_panic_hook();
    }

    let deadline = opts.sweep_deadline.map(Deadline::after);
    let cursor = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<Result<R, JobError>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    let slots: Vec<WorkerSlot> = (0..threads).map(|_| WorkerSlot::new()).collect();
    let stop = AtomicBool::new(false);
    let live_workers = AtomicUsize::new(threads);

    // Register the spawned workers against the global thread budget so
    // nested inner fan-outs (par_map_extra via budget_acquire) only
    // borrow lanes this pool is not already using. The watchdog is not
    // CPU-bound and is not counted.
    let _budget = budget_charge(threads);

    std::thread::scope(|scope| {
        for w in 0..threads {
            let cursor = &cursor;
            let results = &results;
            let slots = &slots;
            let stop = &stop;
            let live_workers = &live_workers;
            let f = &f;
            scope.spawn(move || {
                trace::set_thread_label(&format!("worker-{w}"));
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    if stop.load(Ordering::Acquire) {
                        // Sweep budget exhausted: drain the queue,
                        // recording each unstarted job as cancelled.
                        metrics::counter("pool.cancels").incr();
                        *results[i].lock().unwrap() = Some(Err(JobError {
                            index: i,
                            attempts: 0,
                            kind: JobErrorKind::Cancelled,
                            message: "sweep deadline exceeded before the job started"
                                .to_string(),
                        }));
                        continue;
                    }
                    let r = if bounded {
                        let token = CancelToken::new();
                        slots[w].arm(i, token.clone());
                        let guard = cancel::install(token.clone());
                        let r = run_caught(items, i, max_retries, Some(&token), f);
                        drop(guard);
                        slots[w].disarm();
                        r
                    } else {
                        run_caught(items, i, max_retries, None, f)
                    };
                    *results[i].lock().unwrap() = Some(r);
                }
                live_workers.fetch_sub(1, Ordering::Release);
            });
        }
        if bounded {
            let slots = &slots;
            let stop = &stop;
            let live_workers = &live_workers;
            let job_timeout = opts.job_timeout;
            scope.spawn(move || {
                trace::set_thread_label("watchdog");
                watchdog(slots, stop, live_workers, job_timeout, deadline);
            });
        }
    });

    results
        .into_iter()
        .enumerate()
        .map(|(i, m)| {
            // Every slot is filled: run_caught traps panics (including
            // cancellation unwinds), and stopped workers record their
            // claimed indices as cancelled before moving on.
            m.into_inner()
                .unwrap_or_else(|p| p.into_inner())
                .unwrap_or_else(|| {
                    unreachable!("job {i}/{n}: worker exited without storing a result")
                })
        })
        .collect()
}

/// Parallel-map over an index range `0..n` (avoids materializing inputs).
pub fn par_map_range<R, F>(n: usize, threads: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let idx: Vec<usize> = (0..n).collect();
    par_map(&idx, threads, |&i| f(i))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = par_map(&items, 8, |&x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn runs_every_item_exactly_once() {
        let count = AtomicU64::new(0);
        let items: Vec<u32> = (0..500).collect();
        let _ = par_map(&items, 4, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 500);
    }

    #[test]
    fn empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(par_map(&empty, 4, |&x| x).is_empty());
        assert_eq!(par_map(&[7u32], 4, |&x| x + 1), vec![8]);
    }

    #[test]
    fn single_thread_path() {
        let items: Vec<u32> = (0..10).collect();
        assert_eq!(par_map(&items, 1, |&x| x), items);
    }

    #[test]
    fn range_variant() {
        assert_eq!(par_map_range(5, 3, |i| i * i), vec![0, 1, 4, 9, 16]);
    }

    #[test]
    fn retry_backoff_schedule_starts_at_5ms() {
        // Pinned: first retry sleeps 5 ms, then doubles to the 200 ms cap.
        let sched: Vec<u64> = (1..=9).map(retry_backoff_ms).collect();
        assert_eq!(sched, vec![5, 10, 20, 40, 80, 160, 200, 200, 200]);
    }

    #[test]
    fn catch_reports_failed_job_identity() {
        let items: Vec<u32> = (0..20).collect();
        let out = par_map_catch(&items, 4, 1, |&x| {
            if x == 7 {
                panic!("item seven is cursed");
            }
            x * 2
        });
        assert_eq!(out.len(), 20);
        for (i, r) in out.iter().enumerate() {
            if i == 7 {
                let e = r.as_ref().unwrap_err();
                assert_eq!(e.index, 7);
                assert_eq!(e.attempts, 2);
                assert_eq!(e.kind, JobErrorKind::Panicked);
                assert!(e.message.contains("cursed"), "message={}", e.message);
            } else {
                assert_eq!(*r.as_ref().unwrap(), i as u32 * 2);
            }
        }
    }

    #[test]
    fn catch_retry_clears_transient_panics() {
        use std::sync::atomic::AtomicU32;
        let first_try = AtomicU32::new(0);
        let items: Vec<u32> = (0..8).collect();
        let out = par_map_catch(&items, 4, 2, |&x| {
            if x == 3 && first_try.fetch_add(1, Ordering::Relaxed) == 0 {
                panic!("transient");
            }
            x + 1
        });
        let vals: Vec<u32> = out.into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(vals, (1..=8).collect::<Vec<_>>());
    }

    #[test]
    fn catch_preserves_order_and_handles_empty() {
        let empty: Vec<u32> = vec![];
        assert!(par_map_catch(&empty, 4, 0, |&x| x).is_empty());
        let items: Vec<u64> = (0..100).collect();
        let out = par_map_catch(&items, 8, 0, |&x| x * x);
        let vals: Vec<u64> = out.into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(vals, (0..100).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn catch_single_thread_path_isolates_panics() {
        let items: Vec<u32> = (0..4).collect();
        let out = par_map_catch(&items, 1, 0, |&x| {
            if x == 2 {
                panic!("boom");
            }
            x
        });
        assert!(out[2].is_err());
        assert_eq!(out.iter().filter(|r| r.is_ok()).count(), 3);
    }

    #[test]
    fn opts_without_deadlines_matches_classic_behavior() {
        let items: Vec<u64> = (0..50).collect();
        let out = par_map_catch_opts(&items, &PoolOptions::new(4, 0), |&x| x + 1);
        let vals: Vec<u64> = out.into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(vals, (1..=50).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_extra_matches_serial_for_any_lane_count() {
        let items: Vec<u64> = (0..257).collect();
        let want: Vec<u64> = items.iter().map(|&x| x * 3 + 1).collect();
        for extra in [0, 1, 2, 7] {
            assert_eq!(par_map_extra(&items, extra, |&x| x * 3 + 1), want);
        }
        let empty: Vec<u64> = vec![];
        assert!(par_map_extra(&empty, 4, |&x| x).is_empty());
        // extra is clamped to items.len() - 1, so a single item runs on
        // the calling thread alone.
        assert_eq!(par_map_extra(&[9u64], 8, |&x| x + 1), vec![10]);
    }

    #[test]
    fn par_map_extra_runs_every_item_exactly_once() {
        let count = AtomicU64::new(0);
        let items: Vec<u32> = (0..500).collect();
        let _ = par_map_extra(&items, 3, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 500);
    }

    #[test]
    fn par_map_extra_preserves_panic_payload() {
        let items: Vec<u32> = (0..64).collect();
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            par_map_extra(&items, 3, |&x| {
                if x == 13 {
                    panic!("original payload intact");
                }
                x
            })
        }));
        let payload = caught.expect_err("panic must propagate to the caller");
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .map(str::to_string)
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap();
        assert!(msg.contains("original payload intact"), "msg={msg}");
    }

    #[test]
    fn par_map_extra_propagates_cancellation_to_borrowed_lanes() {
        // A pre-cancelled token installed on the caller must reach every
        // lane: each job polls, unwinds with the marker, and the marker
        // payload is re-raised on the caller (so the outer run_caught
        // boundary classifies it as cancelled, not panicked).
        install_cancel_panic_hook();
        let token = cancel::CancelToken::new();
        let _guard = cancel::install(token.clone());
        token.cancel(CancelReason::Shutdown);
        let items: Vec<u32> = (0..32).collect();
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            par_map_extra(&items, 3, |&x| {
                cancel::poll();
                x
            })
        }));
        let payload = caught.expect_err("cancelled map must unwind");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap();
        assert!(msg.contains(cancel::CANCEL_MARKER), "msg={msg}");
    }

    #[test]
    fn budget_acquire_never_exceeds_total_and_releases_on_drop() {
        // Other tests in this binary use the budget concurrently, so only
        // invariants that hold under interleaving are asserted.
        let total = budget_total();
        assert!(total >= 1);
        let a = budget_acquire(0);
        assert_eq!(a.extra(), 0);
        let b = budget_acquire(usize::MAX >> 1);
        assert!(b.extra() <= total, "lease {} > budget {total}", b.extra());
        assert!(budget_in_use() >= b.extra());
        let before = budget_in_use();
        drop(b);
        assert!(budget_in_use() <= before);
    }

    #[test]
    fn actually_parallel_under_contention() {
        // Smoke check that heavy jobs complete correctly with many threads.
        let out = par_map_range(64, 16, |i| {
            let mut acc = 0u64;
            for k in 0..50_000u64 {
                acc = acc.wrapping_mul(31).wrapping_add(k ^ i as u64);
            }
            acc
        });
        let seq = par_map_range(64, 1, |i| {
            let mut acc = 0u64;
            for k in 0..50_000u64 {
                acc = acc.wrapping_mul(31).wrapping_add(k ^ i as u64);
            }
            acc
        });
        assert_eq!(out, seq);
    }
}
