//! Scoped parallel-map over OS threads (no `rayon`/`tokio` offline).
//!
//! The experiment coordinator fans hundreds of independent simulations out
//! across cores; each job is CPU-bound and seconds-long, so a simple
//! work-stealing-free chunked scheduler with an atomic cursor is plenty.

use crate::util::json::Json;
use crate::util::telemetry::{self, metrics, trace};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Number of worker threads to use: the `DAMOV_THREADS` env var if set,
/// otherwise available parallelism (min 1).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("DAMOV_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Apply `f` to every item of `items` in parallel, preserving order of
/// results. `f` must be `Sync` (called concurrently from many threads).
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return items.iter().map(|t| f(t)).collect();
    }

    let cursor = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });

    results
        .into_iter()
        .enumerate()
        .map(|(i, m)| {
            m.into_inner()
                .unwrap_or_else(|p| p.into_inner())
                .unwrap_or_else(|| panic!("worker panicked while running job {i}/{n}"))
        })
        .collect()
}

/// A job that panicked on every attempt.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobError {
    /// Index of the failed item in the input slice.
    pub index: usize,
    /// Number of attempts made (1 + retries).
    pub attempts: u32,
    /// Panic payload of the last attempt, stringified.
    pub message: String,
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "job {} failed after {} attempt(s): {}",
            self.index, self.attempts, self.message
        )
    }
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run one job with panic isolation and bounded retry. Backoff is
/// exponential starting at 5 ms, capped at 200 ms — transient faults
/// (I/O pressure, injected panics) clear quickly; deterministic bugs
/// fail fast with their identity attached.
fn run_caught<T, R, F>(items: &[T], i: usize, max_retries: u32, f: &F) -> Result<R, JobError>
where
    T: Sync,
    F: Fn(&T) -> R + Sync,
{
    metrics::counter("pool.jobs").incr();
    let mut attempt = 0u32;
    loop {
        // Span guard lives outside the unwind boundary so its E event
        // fires even when the job panics.
        let span = trace::span_args("job", vec![("job".to_string(), Json::from(i as u64))]);
        let caught = catch_unwind(AssertUnwindSafe(|| f(&items[i])));
        drop(span);
        match caught {
            Ok(r) => return Ok(r),
            Err(payload) => {
                metrics::counter("pool.panics").incr();
                let message = panic_message(payload);
                if attempt >= max_retries {
                    metrics::counter("pool.failures").incr();
                    return Err(JobError {
                        index: i,
                        attempts: attempt + 1,
                        message,
                    });
                }
                attempt += 1;
                metrics::counter("pool.retries").incr();
                let backoff = (5u64 << attempt.min(6)).min(200);
                telemetry::warn(
                    "retry",
                    &[
                        ("site", Json::from("pool")),
                        ("job", Json::from(i as u64)),
                        ("attempt", Json::from(attempt as u64)),
                        ("backoff_ms", Json::from(backoff)),
                        ("error", Json::from(message)),
                    ],
                );
                std::thread::sleep(Duration::from_millis(backoff));
            }
        }
    }
}

/// Fallible sibling of [`par_map`]: apply `f` to every item in parallel,
/// catching worker panics instead of aborting the whole map. Each result
/// slot reports either the value or a [`JobError`] naming the failed
/// item, so one bad job costs one record, not the whole sweep. Panicking
/// jobs are retried up to `max_retries` times with exponential backoff
/// before being recorded as failed. Order is preserved.
pub fn par_map_catch<T, R, F>(
    items: &[T],
    threads: usize,
    max_retries: u32,
    f: F,
) -> Vec<Result<R, JobError>>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return (0..n).map(|i| run_caught(items, i, max_retries, &f)).collect();
    }

    let cursor = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<Result<R, JobError>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for w in 0..threads {
            let cursor = &cursor;
            let results = &results;
            let f = &f;
            scope.spawn(move || {
                trace::set_thread_label(&format!("worker-{w}"));
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let r = run_caught(items, i, max_retries, f);
                    *results[i].lock().unwrap() = Some(r);
                }
            });
        }
    });

    results
        .into_iter()
        .enumerate()
        .map(|(i, m)| {
            // Every slot is filled: run_caught traps panics, so workers
            // always store a Result before moving on.
            m.into_inner()
                .unwrap_or_else(|p| p.into_inner())
                .unwrap_or_else(|| {
                    unreachable!("job {i}/{n}: worker exited without storing a result")
                })
        })
        .collect()
}

/// Parallel-map over an index range `0..n` (avoids materializing inputs).
pub fn par_map_range<R, F>(n: usize, threads: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let idx: Vec<usize> = (0..n).collect();
    par_map(&idx, threads, |&i| f(i))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = par_map(&items, 8, |&x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn runs_every_item_exactly_once() {
        let count = AtomicU64::new(0);
        let items: Vec<u32> = (0..500).collect();
        let _ = par_map(&items, 4, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 500);
    }

    #[test]
    fn empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(par_map(&empty, 4, |&x| x).is_empty());
        assert_eq!(par_map(&[7u32], 4, |&x| x + 1), vec![8]);
    }

    #[test]
    fn single_thread_path() {
        let items: Vec<u32> = (0..10).collect();
        assert_eq!(par_map(&items, 1, |&x| x), items);
    }

    #[test]
    fn range_variant() {
        assert_eq!(par_map_range(5, 3, |i| i * i), vec![0, 1, 4, 9, 16]);
    }

    #[test]
    fn catch_reports_failed_job_identity() {
        let items: Vec<u32> = (0..20).collect();
        let out = par_map_catch(&items, 4, 1, |&x| {
            if x == 7 {
                panic!("item seven is cursed");
            }
            x * 2
        });
        assert_eq!(out.len(), 20);
        for (i, r) in out.iter().enumerate() {
            if i == 7 {
                let e = r.as_ref().unwrap_err();
                assert_eq!(e.index, 7);
                assert_eq!(e.attempts, 2);
                assert!(e.message.contains("cursed"), "message={}", e.message);
            } else {
                assert_eq!(*r.as_ref().unwrap(), i as u32 * 2);
            }
        }
    }

    #[test]
    fn catch_retry_clears_transient_panics() {
        use std::sync::atomic::AtomicU32;
        let first_try = AtomicU32::new(0);
        let items: Vec<u32> = (0..8).collect();
        let out = par_map_catch(&items, 4, 2, |&x| {
            if x == 3 && first_try.fetch_add(1, Ordering::Relaxed) == 0 {
                panic!("transient");
            }
            x + 1
        });
        let vals: Vec<u32> = out.into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(vals, (1..=8).collect::<Vec<_>>());
    }

    #[test]
    fn catch_preserves_order_and_handles_empty() {
        let empty: Vec<u32> = vec![];
        assert!(par_map_catch(&empty, 4, 0, |&x| x).is_empty());
        let items: Vec<u64> = (0..100).collect();
        let out = par_map_catch(&items, 8, 0, |&x| x * x);
        let vals: Vec<u64> = out.into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(vals, (0..100).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn catch_single_thread_path_isolates_panics() {
        let items: Vec<u32> = (0..4).collect();
        let out = par_map_catch(&items, 1, 0, |&x| {
            if x == 2 {
                panic!("boom");
            }
            x
        });
        assert!(out[2].is_err());
        assert_eq!(out.iter().filter(|r| r.is_ok()).count(), 3);
    }

    #[test]
    fn actually_parallel_under_contention() {
        // Smoke check that heavy jobs complete correctly with many threads.
        let out = par_map_range(64, 16, |i| {
            let mut acc = 0u64;
            for k in 0..50_000u64 {
                acc = acc.wrapping_mul(31).wrapping_add(k ^ i as u64);
            }
            acc
        });
        let seq = par_map_range(64, 1, |i| {
            let mut acc = 0u64;
            for k in 0..50_000u64 {
                acc = acc.wrapping_mul(31).wrapping_add(k ^ i as u64);
            }
            acc
        });
        assert_eq!(out, seq);
    }
}
