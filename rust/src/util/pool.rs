//! Scoped parallel-map over OS threads (no `rayon`/`tokio` offline).
//!
//! The experiment coordinator fans hundreds of independent simulations out
//! across cores; each job is CPU-bound and seconds-long, so a simple
//! work-stealing-free chunked scheduler with an atomic cursor is plenty.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use: the `DAMOV_THREADS` env var if set,
/// otherwise available parallelism (min 1).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("DAMOV_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Apply `f` to every item of `items` in parallel, preserving order of
/// results. `f` must be `Sync` (called concurrently from many threads).
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return items.iter().map(|t| f(t)).collect();
    }

    let cursor = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });

    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker panicked before storing result"))
        .collect()
}

/// Parallel-map over an index range `0..n` (avoids materializing inputs).
pub fn par_map_range<R, F>(n: usize, threads: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let idx: Vec<usize> = (0..n).collect();
    par_map(&idx, threads, |&i| f(i))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = par_map(&items, 8, |&x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn runs_every_item_exactly_once() {
        let count = AtomicU64::new(0);
        let items: Vec<u32> = (0..500).collect();
        let _ = par_map(&items, 4, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 500);
    }

    #[test]
    fn empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(par_map(&empty, 4, |&x| x).is_empty());
        assert_eq!(par_map(&[7u32], 4, |&x| x + 1), vec![8]);
    }

    #[test]
    fn single_thread_path() {
        let items: Vec<u32> = (0..10).collect();
        assert_eq!(par_map(&items, 1, |&x| x), items);
    }

    #[test]
    fn range_variant() {
        assert_eq!(par_map_range(5, 3, |i| i * i), vec![0, 1, 4, 9, 16]);
    }

    #[test]
    fn actually_parallel_under_contention() {
        // Smoke check that heavy jobs complete correctly with many threads.
        let out = par_map_range(64, 16, |i| {
            let mut acc = 0u64;
            for k in 0..50_000u64 {
                acc = acc.wrapping_mul(31).wrapping_add(k ^ i as u64);
            }
            acc
        });
        let seq = par_map_range(64, 1, |i| {
            let mut acc = 0u64;
            for k in 0..50_000u64 {
                acc = acc.wrapping_mul(31).wrapping_add(k ^ i as u64);
            }
            acc
        });
        assert_eq!(out, seq);
    }
}
