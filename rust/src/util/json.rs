//! Minimal JSON value model, emitter and parser.
//!
//! The offline crate set has no `serde`/`serde_json`, and the results
//! database + report outputs need a structured interchange format, so this
//! module implements the small subset of JSON we use: objects, arrays,
//! strings, finite f64 numbers, booleans, null. Numbers are emitted with
//! enough precision to round-trip f64; NaN/inf are emitted as null (JSON
//! has no representation for them).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable —
/// results files diff cleanly between runs.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val.into());
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        out.push_str(&format!("{}", *x as i64));
                    } else {
                        out.push_str(&format!("{x:?}")); // Debug f64 round-trips
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document. Returns an error with byte offset on failure.
    pub fn parse(input: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                other => {
                    return Err(format!(
                        "expected ',' or '}}' at byte {}, found {:?}",
                        self.pos,
                        other.map(|c| c as char)
                    ))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                other => {
                    return Err(format!(
                        "expected ',' or ']' at byte {}, found {:?}",
                        self.pos,
                        other.map(|c| c as char)
                    ))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| "bad \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte safe).
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    let end = (start + len).min(self.bytes.len());
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| "invalid utf8".to_string())?,
                    );
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {text:?}: {e}"))
    }
}

fn utf8_len(first: u8) -> usize {
    if first < 0x80 {
        1
    } else if first < 0xE0 {
        2
    } else if first < 0xF0 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let mut j = Json::obj();
        j.set("name", "STRTriad")
            .set("mpki", 47.25)
            .set("cores", vec![1u64, 4, 16, 64, 256])
            .set("ndp_wins", true)
            .set("note", Json::Null);
        let text = j.to_string_pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[{"b":1e-3},{"c":"x\ny"}],"d":-42}"#).unwrap();
        assert_eq!(j.get("d").unwrap().as_f64(), Some(-42.0));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].get("b").unwrap().as_f64(), Some(1e-3));
        assert_eq!(arr[1].get("c").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::Str("quote\" slash\\ tab\t nl\n ctrl\u{1}".into());
        let text = j.to_string_compact();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn f64_precision_roundtrips() {
        let j = Json::Num(0.1 + 0.2);
        let back = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(back.as_f64(), Some(0.1 + 0.2));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn nonfinite_emitted_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string_compact(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string_compact(), "null");
    }

    #[test]
    fn unicode_string_roundtrip() {
        let j = Json::Str("naïve — ü 工".into());
        assert_eq!(Json::parse(&j.to_string_compact()).unwrap(), j);
    }
}
