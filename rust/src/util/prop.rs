//! Minimal property-based testing harness (the offline crate set has no
//! `proptest`, so this provides the same discipline: many seeded random
//! cases per property, with the failing seed printed for reproduction).
//!
//! Usage:
//! ```ignore
//! prop::check(200, |rng| {
//!     let n = rng.gen_usize(1, 100);
//!     // ... build inputs from rng, assert the invariant ...
//! });
//! ```
//! On failure the panic message includes `case` and `seed`; re-run with
//! `prop::check_seeded(seed, ...)` to reproduce a single case.

use super::rng::Xoshiro256;

/// Base seed; override with env `DAMOV_PROP_SEED` to explore other regions.
fn base_seed() -> u64 {
    std::env::var("DAMOV_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xDA40_71E5_7EED_5EED)
}

/// Run `property` against `cases` independently-seeded RNGs. Panics (with
/// the reproducing seed) if any case panics.
pub fn check<F: Fn(&mut Xoshiro256) + std::panic::RefUnwindSafe>(cases: usize, property: F) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Xoshiro256::new(seed);
            property(&mut rng);
        });
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!("property failed at case {case} (seed={seed:#x}): {msg}");
        }
    }
}

/// Re-run a single failing case by seed.
pub fn check_seeded<F: FnOnce(&mut Xoshiro256)>(seed: u64, property: F) {
    let mut rng = Xoshiro256::new(seed);
    property(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_quiet_property() {
        check(50, |rng| {
            let a = rng.gen_range(1000) as i64;
            let b = rng.gen_range(1000) as i64;
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn reports_seed_on_failure() {
        let result = std::panic::catch_unwind(|| {
            check(50, |rng| {
                // Fails for roughly half of the cases.
                assert!(rng.gen_f64() < 0.5, "drew a large value");
            });
        });
        let err = result.expect_err("property should fail");
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("seed="), "message was: {msg}");
    }

    #[test]
    fn seeded_rerun_is_deterministic() {
        let mut first = None;
        check_seeded(42, |rng| first = Some(rng.next_u64()));
        let mut second = None;
        check_seeded(42, |rng| second = Some(rng.next_u64()));
        assert_eq!(first, second);
    }
}
