//! Step 2: architecture-independent spatial/temporal locality metrics
//! (paper §2.3, Eqs. 1–2; definitions after Weinberg et al. / Shao &
//! Brooks), computed at **word granularity** over the function's
//! single-thread trace.
//!
//! The trace is split into non-overlapping windows of W = L = 32 word
//! addresses:
//!
//! * **Spatial** (Eq. 1): per window, the minimum non-zero distance
//!   between any two addresses (`stride`); the metric is the mean over
//!   windows of `1/stride` (a window with no two distinct addresses
//!   contributes 0). Fully sequential word accesses → 1; large or random
//!   strides → ~0.
//! * **Temporal** (Eq. 2): per window, each address appearing k ≥ 2
//!   times contributes `2^floor(log2 k)`; the metric is the summed
//!   contribution divided by total accesses. A single address repeated
//!   forever → 1; all-unique addresses → 0.
//!
//! This module is the **reference implementation and oracle** for the
//! AOT-compiled Pallas kernel (`python/compile/kernels/locality.py`); the
//! runtime cross-checks both paths (see `runtime::analytics`). The exact
//! same windowed formulation is used on both sides so results match to
//! floating-point rounding.

use crate::sim::Access;

pub const WINDOW: usize = 32;

/// Spatial/temporal locality of one function.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LocalityMetrics {
    pub spatial: f64,
    pub temporal: f64,
    /// Number of full windows analyzed.
    pub windows: usize,
}

/// Convert a trace to word addresses (8-byte words, §2.3 footnote 5).
pub fn word_trace(trace: &[Access]) -> Vec<u64> {
    trace.iter().map(|a| a.addr >> 3).collect()
}

/// Per-window spatial contribution: 1 / min non-zero pairwise distance,
/// or 0 if all addresses are identical.
pub fn window_spatial(window: &[u64]) -> f64 {
    debug_assert!(window.len() >= 2);
    let mut min_stride = u64::MAX;
    for i in 0..window.len() {
        for j in (i + 1)..window.len() {
            let d = window[i].abs_diff(window[j]);
            if d > 0 && d < min_stride {
                min_stride = d;
            }
        }
    }
    if min_stride == u64::MAX {
        0.0
    } else {
        1.0 / min_stride as f64
    }
}

/// Per-window temporal contribution: Σ over positions of
/// `[k_i >= 2] * 2^floor(log2 k_i) / k_i`, where `k_i` is the number of
/// occurrences of the address at position i within the window. (Each
/// unique address thus contributes `2^floor(log2 k)` once, matching the
/// reuse-profile formulation; dividing by window length outside yields
/// Eq. 2.)
pub fn window_temporal(window: &[u64]) -> f64 {
    let n = window.len();
    let mut total = 0.0;
    for i in 0..n {
        let mut k = 0u32;
        for j in 0..n {
            if window[j] == window[i] {
                k += 1;
            }
        }
        if k >= 2 {
            let bin = 31 - k.leading_zeros(); // floor(log2 k)
            total += (1u64 << bin) as f64 / k as f64;
        }
    }
    total
}

/// Compute both metrics over a word-address stream.
///
/// Hot path: instead of the O(W²) pairwise scans (kept above as the
/// definitional forms, and mirrored by the Pallas kernel where the
/// broadcast compare *is* the natural vector shape), each window is
/// sorted once — the min non-zero pairwise distance is the min non-zero
/// adjacent difference of the sorted window, and occurrence counts are
/// its run lengths. Exactly equivalent, ~3x faster in scalar code.
pub fn locality_of_words(words: &[u64]) -> LocalityMetrics {
    let windows = words.len() / WINDOW;
    if windows == 0 {
        return LocalityMetrics {
            spatial: 0.0,
            temporal: 0.0,
            windows: 0,
        };
    }
    let mut spatial_sum = 0.0;
    let mut temporal_sum = 0.0;
    let mut buf = [0u64; WINDOW];
    for w in 0..windows {
        buf.copy_from_slice(&words[w * WINDOW..(w + 1) * WINDOW]);
        buf.sort_unstable();
        let mut min_stride = u64::MAX;
        let mut run = 1u32;
        for i in 1..WINDOW {
            let d = buf[i] - buf[i - 1];
            if d == 0 {
                run += 1;
            } else {
                if d < min_stride {
                    min_stride = d;
                }
                if run >= 2 {
                    temporal_sum += (1u64 << (31 - run.leading_zeros())) as f64;
                }
                run = 1;
            }
        }
        if run >= 2 {
            temporal_sum += (1u64 << (31 - run.leading_zeros())) as f64;
        }
        if min_stride != u64::MAX {
            spatial_sum += 1.0 / min_stride as f64;
        }
    }
    LocalityMetrics {
        spatial: (spatial_sum / windows as f64).min(1.0),
        temporal: (temporal_sum / (windows * WINDOW) as f64).min(1.0),
        windows,
    }
}

/// Definitional (O(W²)) implementation retained as a cross-check oracle
/// for the sorted fast path.
pub fn locality_of_words_reference(words: &[u64]) -> LocalityMetrics {
    let windows = words.len() / WINDOW;
    if windows == 0 {
        return LocalityMetrics {
            spatial: 0.0,
            temporal: 0.0,
            windows: 0,
        };
    }
    let mut spatial_sum = 0.0;
    let mut temporal_sum = 0.0;
    for w in 0..windows {
        let win = &words[w * WINDOW..(w + 1) * WINDOW];
        spatial_sum += window_spatial(win);
        temporal_sum += window_temporal(win);
    }
    LocalityMetrics {
        spatial: (spatial_sum / windows as f64).min(1.0),
        temporal: (temporal_sum / (windows * WINDOW) as f64).min(1.0),
        windows,
    }
}

/// Compute both metrics for an access trace.
pub fn locality(trace: &[Access]) -> LocalityMetrics {
    locality_of_words(&word_trace(trace))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn words_to_accesses(words: &[u64]) -> Vec<Access> {
        words.iter().map(|&w| Access::load(w * 8, 0, 0)).collect()
    }

    #[test]
    fn sequential_words_spatial_one() {
        let words: Vec<u64> = (0..320).collect();
        let m = locality_of_words(&words);
        assert!((m.spatial - 1.0).abs() < 1e-12, "spatial={}", m.spatial);
        assert_eq!(m.temporal, 0.0);
    }

    #[test]
    fn single_address_temporal_one() {
        let words = vec![42u64; 320];
        let m = locality_of_words(&words);
        // k = 32 per window: 2^5 / 32 = 1.0 exactly.
        assert!((m.temporal - 1.0).abs() < 1e-12, "temporal={}", m.temporal);
        assert_eq!(m.spatial, 0.0); // no two distinct addresses
    }

    #[test]
    fn random_trace_low_both() {
        let mut rng = crate::util::rng::Xoshiro256::new(3);
        let words: Vec<u64> = (0..3200).map(|_| rng.gen_range(1 << 40)).collect();
        let m = locality_of_words(&words);
        assert!(m.spatial < 0.05, "spatial={}", m.spatial);
        assert!(m.temporal < 0.05, "temporal={}", m.temporal);
    }

    #[test]
    fn strided_access_spatial_inverse_stride() {
        let words: Vec<u64> = (0..320).map(|i| i * 4).collect();
        let m = locality_of_words(&words);
        assert!((m.spatial - 0.25).abs() < 1e-12, "spatial={}", m.spatial);
    }

    #[test]
    fn alternating_pair_temporal_one() {
        let words: Vec<u64> = (0..320).map(|i| (i % 2) as u64).collect();
        let m = locality_of_words(&words);
        // Each window: 2 addresses x k=16 -> 2 * 2^4 = 32; /32 = 1.0.
        assert!((m.temporal - 1.0).abs() < 1e-12, "temporal={}", m.temporal);
        // min distinct stride = 1.
        assert!((m.spatial - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rmw_triplets_intermediate_temporal() {
        // load,load,store to each word (k=3 within window mostly).
        let mut words = Vec::new();
        for i in 0..400u64 {
            words.extend_from_slice(&[i, i, i]);
        }
        let m = locality_of_words(&words);
        // Triples: 2^1/3*3 per address = 2 per address; ~10.67 addr/window
        // -> ~21/32 = 0.66 (boundary effects shift it slightly).
        assert!((0.5..0.8).contains(&m.temporal), "temporal={}", m.temporal);
        assert!(m.spatial > 0.9); // adjacent words present
    }

    #[test]
    fn partial_window_ignored() {
        let words: Vec<u64> = (0..40).collect(); // 1 full window + 8 extra
        let m = locality_of_words(&words);
        assert_eq!(m.windows, 1);
    }

    #[test]
    fn empty_trace() {
        let m = locality_of_words(&[]);
        assert_eq!(m.windows, 0);
        assert_eq!(m.spatial, 0.0);
    }

    #[test]
    fn trace_api_uses_word_granularity() {
        // Byte addresses 0,8,16.. = words 0,1,2..
        let accesses = words_to_accesses(&(0..64).collect::<Vec<u64>>());
        let m = locality(&accesses);
        assert!((m.spatial - 1.0).abs() < 1e-12);
    }

    #[test]
    fn suite_classes_separate_in_temporal() {
        use crate::workloads::{registry, Scale};
        // STREAM (1a) must be low-temporal; GramSch (2a) high-temporal.
        let stream = registry::by_code("STRTriad").unwrap();
        let gram = registry::by_code("PLYGramSch").unwrap();
        let mt_stream = locality(&stream.locality_trace(Scale::tiny()));
        let mt_gram = locality(&gram.locality_trace(Scale::tiny()));
        assert!(
            mt_gram.temporal > mt_stream.temporal + 0.3,
            "gram={} stream={}",
            mt_gram.temporal,
            mt_stream.temporal
        );
        assert!(mt_stream.spatial > 0.5, "stream spatial={}", mt_stream.spatial);
    }

    #[test]
    fn fast_path_matches_definitional_form() {
        crate::util::prop::check(60, |rng| {
            let kind = rng.gen_usize(0, 4);
            let n = rng.gen_usize(32, 400);
            let words: Vec<u64> = match kind {
                0 => (0..n).map(|_| rng.gen_range(1 << 40)).collect(),
                1 => (0..n as u64).collect(),
                2 => (0..n).map(|_| rng.gen_range(8)).collect(), // heavy repeats
                _ => (0..n as u64).map(|i| i * rng.gen_range(100).max(1)).collect(),
            };
            let fast = locality_of_words(&words);
            let slow = locality_of_words_reference(&words);
            assert!((fast.spatial - slow.spatial).abs() < 1e-12);
            assert!((fast.temporal - slow.temporal).abs() < 1e-12);
        });
    }

    #[test]
    fn property_metrics_bounded() {
        crate::util::prop::check(50, |rng| {
            let n = rng.gen_usize(0, 500);
            let words: Vec<u64> = (0..n).map(|_| rng.gen_range(1 << 20)).collect();
            let m = locality_of_words(&words);
            assert!((0.0..=1.0).contains(&m.spatial));
            assert!((0.0..=1.0).contains(&m.temporal));
        });
    }

    #[test]
    fn property_permuting_windows_preserves_metrics() {
        // Metrics are window-local: shuffling whole windows changes nothing.
        crate::util::prop::check(20, |rng| {
            let n_win = rng.gen_usize(2, 20);
            let mut words = Vec::new();
            for _ in 0..n_win * WINDOW {
                words.push(rng.gen_range(1000));
            }
            let base = locality_of_words(&words);
            // Swap two whole windows.
            let a = rng.gen_usize(0, n_win);
            let b = rng.gen_usize(0, n_win);
            let mut swapped = words.clone();
            for k in 0..WINDOW {
                swapped.swap(a * WINDOW + k, b * WINDOW + k);
            }
            let after = locality_of_words(&swapped);
            assert!((base.spatial - after.spatial).abs() < 1e-12);
            assert!((base.temporal - after.temporal).abs() < 1e-12);
        });
    }
}
