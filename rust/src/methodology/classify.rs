//! Memory-bottleneck classification (paper §3.3) and its validation
//! (§3.5.1).
//!
//! The six classes are defined over five features: temporal locality
//! (Step 2), AI, LLC MPKI, LFMR level and LFMR slope over the core sweep
//! (Step 3):
//!
//! | class | temporal | AI   | MPKI | LFMR        | bottleneck          |
//! |-------|----------|------|------|-------------|---------------------|
//! | 1a    | low      | low  | high | high        | DRAM bandwidth      |
//! | 1b    | low      | low  | low  | high, const | DRAM latency        |
//! | 1c    | low      | low  | low  | decreasing  | L1/L2 capacity      |
//! | 2a    | high     | low  | low  | increasing  | L3 contention       |
//! | 2b    | high     | low  | low  | low/med     | L1 capacity         |
//! | 2c    | high     | high | low  | low         | compute-bound       |
//!
//! Thresholds are **derived from the 44 representatives** (phase 1: the
//! midpoint between the low-group mean and the high-group mean of each
//! metric), then the 100 held-out variants are classified and scored
//! against their family's ground truth (phase 2). The paper reports
//! 0.48 / 8.5 / 11.0 / 0.56 and 97% accuracy on its corpus.

use super::step3::FunctionProfile;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Class {
    C1a,
    C1b,
    C1c,
    C2a,
    C2b,
    C2c,
}

pub const ALL_CLASSES: [Class; 6] = [
    Class::C1a,
    Class::C1b,
    Class::C1c,
    Class::C2a,
    Class::C2b,
    Class::C2c,
];

impl Class {
    pub fn label(&self) -> &'static str {
        match self {
            Class::C1a => "1a",
            Class::C1b => "1b",
            Class::C1c => "1c",
            Class::C2a => "2a",
            Class::C2b => "2b",
            Class::C2c => "2c",
        }
    }

    pub fn parse(s: &str) -> Option<Class> {
        ALL_CLASSES.iter().copied().find(|c| c.label() == s)
    }

    pub fn description(&self) -> &'static str {
        match self {
            Class::C1a => "DRAM bandwidth-bound",
            Class::C1b => "DRAM latency-bound",
            Class::C1c => "L1/L2 cache capacity-bound",
            Class::C2a => "L3 cache contention-bound",
            Class::C2b => "L1 cache capacity-bound",
            Class::C2c => "compute-bound",
        }
    }
}

/// Classification features of one function.
#[derive(Debug, Clone, Copy)]
pub struct Features {
    pub temporal: f64,
    pub ai: f64,
    pub mpki: f64,
    /// Mean LFMR across the host core sweep.
    pub lfmr: f64,
    /// LFMR(256 cores) − LFMR(1 core).
    pub slope: f64,
}

impl Features {
    pub fn of(p: &FunctionProfile) -> Features {
        Features {
            temporal: p.locality.temporal,
            ai: p.ai,
            mpki: p.mpki,
            lfmr: p.lfmr_mean(),
            slope: p.lfmr_slope(),
        }
    }
}

/// Data-derived decision thresholds (phase 1 of §3.5.1).
#[derive(Debug, Clone, Copy)]
pub struct Thresholds {
    pub temporal: f64,
    pub ai: f64,
    pub mpki: f64,
    pub lfmr: f64,
    /// Slope below which LFMR "decreases with core count".
    pub slope_dec: f64,
    /// Slope above which LFMR "increases with core count".
    pub slope_inc: f64,
}

fn median_of(values: &[f64]) -> f64 {
    let mut v: Vec<f64> = values.iter().copied().filter(|x| x.is_finite()).collect();
    if v.is_empty() {
        return f64::NAN;
    }
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    crate::util::stats::percentile_sorted(&v, 50.0)
}

/// Derive thresholds from labeled representative profiles: for each
/// metric, the midpoint between the median over the classes defined as
/// "low" and the median over the classes defined as "high". Bounded
/// metrics (temporal, LFMR, slope) use the arithmetic midpoint;
/// decade-spanning metrics (MPKI, AI) use the geometric midpoint —
/// medians make both robust to the heavy tails of the suite.
pub fn derive_thresholds(reps: &[(&FunctionProfile, Class)]) -> Thresholds {
    let vals = |pred: &dyn Fn(Class) -> bool, f: &dyn Fn(&Features) -> f64| -> Vec<f64> {
        reps.iter()
            .filter(|(_, c)| pred(*c))
            .map(|(p, _)| f(&Features::of(p)))
            .collect()
    };
    let mid = |lo: Vec<f64>, hi: Vec<f64>| (median_of(&lo) + median_of(&hi)) / 2.0;
    let geomid = |lo: Vec<f64>, hi: Vec<f64>| {
        (median_of(&lo).max(1e-3) * median_of(&hi).max(1e-3)).sqrt()
    };

    use Class::*;
    let temporal = mid(
        vals(&|c| matches!(c, C1a | C1b | C1c), &|f| f.temporal),
        vals(&|c| matches!(c, C2a | C2b | C2c), &|f| f.temporal),
    );
    let ai = geomid(
        vals(&|c| !matches!(c, C2c), &|f| f.ai),
        vals(&|c| matches!(c, C2c), &|f| f.ai),
    );
    let mpki = geomid(
        vals(&|c| !matches!(c, C1a), &|f| f.mpki),
        vals(&|c| matches!(c, C1a), &|f| f.mpki),
    );
    let lfmr = mid(
        vals(&|c| matches!(c, C2b | C2c), &|f| f.lfmr),
        vals(&|c| matches!(c, C1a | C1b), &|f| f.lfmr),
    );
    let slope_const: Vec<f64> = vals(&|c| matches!(c, C1a | C1b | C2b | C2c), &|f| f.slope);
    let slope_dec = (median_of(&vals(&|c| matches!(c, C1c), &|f| f.slope))
        + median_of(&slope_const))
        / 2.0;
    let slope_inc = (median_of(&vals(&|c| matches!(c, C2a), &|f| f.slope))
        + median_of(&slope_const))
        / 2.0;

    Thresholds {
        temporal,
        ai,
        mpki,
        lfmr,
        slope_dec,
        slope_inc,
    }
}

/// Classify one function's features (decision rules of §3.3/Fig 26).
/// Within each temporal-locality group the LFMR *slope* is checked
/// first: a capacity/contention signature (1c/2a) overrides the
/// instantaneous intensity metrics measured at the reference point.
pub fn classify(f: &Features, t: &Thresholds) -> Class {
    if f.temporal < t.temporal {
        // Low temporal locality: 1a / 1b / 1c.
        if f.slope <= t.slope_dec {
            Class::C1c
        } else if f.mpki >= t.mpki {
            Class::C1a
        } else {
            Class::C1b
        }
    } else {
        // High temporal locality: 2a / 2b / 2c.
        if f.ai >= t.ai {
            Class::C2c
        } else if f.slope >= t.slope_inc {
            Class::C2a
        } else {
            Class::C2b
        }
    }
}

/// Outcome of the §3.5.1 two-phase validation.
#[derive(Debug, Clone)]
pub struct ValidationReport {
    pub thresholds: Thresholds,
    pub total: usize,
    pub correct: usize,
    /// (code, expected, predicted) for misclassified functions.
    pub errors: Vec<(String, Class, Class)>,
    /// confusion[expected][predicted] counts, indexed per `ALL_CLASSES`.
    pub confusion: [[usize; 6]; 6],
}

impl ValidationReport {
    pub fn accuracy(&self) -> f64 {
        self.correct as f64 / self.total.max(1) as f64
    }
}

fn class_index(c: Class) -> usize {
    ALL_CLASSES.iter().position(|&x| x == c).unwrap()
}

/// Phase 1 + phase 2: derive thresholds from the representatives, then
/// classify the held-out set against family ground truth.
pub fn validate(reps: &[FunctionProfile], holdout: &[FunctionProfile]) -> ValidationReport {
    let labeled: Vec<(&FunctionProfile, Class)> = reps
        .iter()
        .filter_map(|p| p.paper_class.and_then(Class::parse).map(|c| (p, c)))
        .collect();
    let thresholds = derive_thresholds(&labeled);

    let mut correct = 0;
    let mut errors = Vec::new();
    let mut confusion = [[0usize; 6]; 6];
    for p in holdout {
        let expected = Class::parse(p.family_class).expect("valid family class");
        let predicted = classify(&Features::of(p), &thresholds);
        confusion[class_index(expected)][class_index(predicted)] += 1;
        if predicted == expected {
            correct += 1;
        } else {
            errors.push((format!("{}:{}", p.code, p.input), expected, predicted));
        }
    }
    ValidationReport {
        thresholds,
        total: holdout.len(),
        correct,
        errors,
        confusion,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn thresholds() -> Thresholds {
        Thresholds {
            temporal: 0.48,
            ai: 8.5,
            mpki: 11.0,
            lfmr: 0.56,
            slope_dec: -0.3,
            slope_inc: 0.3,
        }
    }

    fn feats(temporal: f64, ai: f64, mpki: f64, lfmr: f64, slope: f64) -> Features {
        Features {
            temporal,
            ai,
            mpki,
            lfmr,
            slope,
        }
    }

    #[test]
    fn paperlike_thresholds_classify_canonical_points() {
        let t = thresholds();
        // STREAM-like.
        assert_eq!(classify(&feats(0.1, 2.0, 50.0, 0.95, 0.0), &t), Class::C1a);
        // Latency-bound.
        assert_eq!(classify(&feats(0.2, 2.0, 5.0, 0.95, 0.0), &t), Class::C1b);
        // L1/L2 capacity.
        assert_eq!(classify(&feats(0.2, 2.0, 5.0, 0.5, -0.8), &t), Class::C1c);
        // L3 contention.
        assert_eq!(classify(&feats(0.6, 2.0, 3.0, 0.4, 0.8), &t), Class::C2a);
        // L1 capacity.
        assert_eq!(classify(&feats(0.6, 2.0, 3.0, 0.3, 0.0), &t), Class::C2b);
        // Compute-bound.
        assert_eq!(classify(&feats(0.7, 30.0, 0.5, 0.05, 0.0), &t), Class::C2c);
    }

    #[test]
    fn class_labels_roundtrip() {
        for c in ALL_CLASSES {
            assert_eq!(Class::parse(c.label()), Some(c));
        }
        assert_eq!(Class::parse("3z"), None);
    }

    #[test]
    fn boundary_cases_are_deterministic() {
        let t = thresholds();
        // Exactly at the MPKI threshold counts as high (>=).
        assert_eq!(classify(&feats(0.1, 2.0, 11.0, 0.9, 0.0), &t), Class::C1a);
        // Exactly at the AI threshold counts as high.
        assert_eq!(classify(&feats(0.6, 8.5, 1.0, 0.1, 0.0), &t), Class::C2c);
    }
}
