//! Step 1: memory-bound function identification (paper §2.2, §3.1).
//!
//! The paper profiles 345 applications with Intel VTune's top-down
//! analysis on a 4-core Xeon and keeps functions with `Memory Bound`
//! > 30% (and ≥ 3% of application cycles). Our substitute computes the
//! same metric — the fraction of pipeline slots lost to data-access
//! stalls — from the simulator's own accounting on the equivalent
//! 4-core host configuration (DESIGN.md §1, substitution S8).

use crate::sim::{simulate, CoreModel, SystemConfig};
use crate::workloads::{FunctionSpec, Scale};

/// The paper's Memory Bound threshold.
pub const MEMORY_BOUND_THRESHOLD: f64 = 0.30;

/// Step-1 verdict for one function.
#[derive(Debug, Clone)]
pub struct Step1Result {
    pub code: String,
    pub memory_bound: f64,
    pub selected: bool,
}

/// Profile one function on the 4-core host and apply the 30% filter.
pub fn identify(spec: &FunctionSpec, scale: Scale) -> Step1Result {
    let cfg = SystemConfig::host(4, CoreModel::OutOfOrder);
    let r = simulate(&cfg, &spec.trace(4, scale));
    Step1Result {
        code: spec.id.code(),
        memory_bound: r.memory_bound,
        selected: r.memory_bound > MEMORY_BOUND_THRESHOLD,
    }
}

/// Run Step 1 over a set of functions, returning those selected.
pub fn filter_memory_bound(specs: &[FunctionSpec], scale: Scale, threads: usize) -> Vec<Step1Result> {
    crate::util::pool::par_map(specs, threads, |s| identify(s, scale))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::registry;

    #[test]
    fn stream_is_selected() {
        let spec = registry::by_code("STRTriad").unwrap();
        let r = identify(&spec, Scale(0.3));
        assert!(r.selected, "memory_bound={}", r.memory_bound);
    }

    #[test]
    fn chase_is_strongly_selected() {
        let spec = registry::by_code("PLYalu").unwrap();
        let r = identify(&spec, Scale(0.3));
        assert!(r.memory_bound > 0.5, "memory_bound={}", r.memory_bound);
    }

    #[test]
    fn all_suite_functions_pass_step1() {
        // The DAMOV suite is by construction the memory-bound subset —
        // every representative must clear the 30% filter.
        let reps = registry::representatives();
        let results = filter_memory_bound(&reps, Scale(0.15), 8);
        for r in &results {
            assert!(
                r.memory_bound > MEMORY_BOUND_THRESHOLD,
                "{} has memory_bound={:.2}",
                r.code,
                r.memory_bound
            );
        }
    }
}
