//! The paper's contribution: the three-step workload characterization
//! methodology (§2) and the six-class memory-bottleneck model (§3).
//!
//! * [`step1`] — memory-bound function identification via top-down
//!   "Memory Bound %" on the simulated host (substitutes VTune).
//! * [`locality`] — Step 2's architecture-independent spatial/temporal
//!   locality metrics (word granularity, 32-reference windows).
//! * [`step3`] — the scalability analysis: three systems × the core
//!   sweep, yielding per-function [`step3::FunctionProfile`]s.
//! * [`classify`] — bottleneck classification: data-derived thresholds
//!   (§3.5.1 phase 1) + the six-class decision rules, and the held-out
//!   validation (§3.5.1 phase 2).
//! * [`cluster`] — K-means (Fig 3) and hierarchical clustering (Fig 19).

pub mod classify;
pub mod cluster;
pub mod locality;
pub mod step1;
pub mod step3;

pub use classify::{Class, Thresholds};
pub use locality::{locality, LocalityMetrics};
pub use step3::FunctionProfile;
