//! Step 3: scalability analysis (paper §2.4).
//!
//! For each function we simulate a configurable list of
//! [`SystemSpec`]s (by default the paper's host, host+prefetcher and
//! NDP; optionally the §3.4 NUCA host and custom JSON specs) across the
//! 1–256 core sweep (and optionally the in-order core model), and
//! collect the classification metrics — AI, LLC MPKI, LFMR (+ its slope
//! over the sweep) — plus everything the report harness needs (energy
//! breakdowns, AMAT, request breakdowns, bandwidth, NoC statistics).

use super::locality::{locality, LocalityMetrics};
use crate::sim::{simulate_events, CoreModel, SimResult, SystemSpec, TraceAnalysis, CORE_SWEEP};
use crate::util::fault;
use crate::util::json::Json;
use crate::util::pool::{self, par_map_catch_opts, JobErrorKind, PoolOptions};
use crate::util::telemetry::{self, metrics};
use crate::workloads::{FunctionSpec, Scale};
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide count of *completed* `profile_function` computations.
/// Observability hook for the resume machinery: lets tests (and
/// `--resume` users) verify that a resumed sweep recomputes only
/// unfinished functions.
///
/// Ordering contract (pinned in `rust/tests/fault_injection.rs`): the
/// increment happens *after* the whole sweep for the function has
/// simulated, immediately before the profile is returned — and therefore
/// (on the same worker thread) before `profile_all_checkpointed`'s
/// completion hook appends the profile to the checkpoint. A panicking,
/// cancelled, or retried attempt never increments, so the counter equals
/// the number of profiles computed to completion and every checkpoint
/// append is preceded by exactly one increment for that profile.
static PROFILE_CALLS: AtomicU64 = AtomicU64::new(0);

/// How many function profiles this process has computed to completion
/// (not cached, not failed attempts). See [`PROFILE_CALLS`].
pub fn profile_call_count() -> u64 {
    PROFILE_CALLS.load(Ordering::Relaxed)
}

/// One simulated (system, core-model, cores) point.
#[derive(Debug, Clone)]
pub struct Run {
    /// Name of the [`SystemSpec`] this point was lowered from.
    pub system: String,
    pub core_model: CoreModel,
    pub cores: usize,
    pub result: SimResult,
}

/// Complete characterization of one function.
#[derive(Debug, Clone)]
pub struct FunctionProfile {
    pub code: String,
    pub input: String,
    pub suite: String,
    pub paper_class: Option<&'static str>,
    pub family_class: &'static str,
    pub representative: bool,
    pub locality: LocalityMetrics,
    /// Reference metrics: host, out-of-order, 4 cores (the Step-1 box).
    pub ai: f64,
    pub mpki: f64,
    pub lfmr: f64,
    pub memory_bound: f64,
    /// LFMR on the host across `CORE_SWEEP`.
    pub lfmr_by_cores: Vec<f64>,
    pub runs: Vec<Run>,
}

impl FunctionProfile {
    pub fn run(&self, system: &str, core_model: CoreModel, cores: usize) -> Option<&Run> {
        self.runs
            .iter()
            .find(|r| r.system == system && r.core_model == core_model && r.cores == cores)
    }

    /// Name of the baseline system: the first system of the sweep this
    /// profile was produced by ("host" for the paper presets).
    pub fn baseline_system(&self) -> &str {
        self.runs.first().map(|r| r.system.as_str()).unwrap_or("")
    }

    /// Performance normalized to one baseline-system core (same model).
    pub fn norm_perf(&self, system: &str, core_model: CoreModel, cores: usize) -> f64 {
        let base = self
            .run(self.baseline_system(), core_model, 1)
            .map(|r| r.result.perf())
            .unwrap_or(1.0);
        self.run(system, core_model, cores)
            .map(|r| r.result.perf() / base)
            .unwrap_or(f64::NAN)
    }

    /// NDP speedup over the host at the same core count (NaN when the
    /// sweep did not include both paper presets).
    pub fn ndp_speedup(&self, core_model: CoreModel, cores: usize) -> f64 {
        let host = self.run("host", core_model, cores).map(|r| r.result.perf());
        let ndp = self.run("ndp", core_model, cores).map(|r| r.result.perf());
        match (host, ndp) {
            (Some(h), Some(n)) if h > 0.0 => n / h,
            _ => f64::NAN,
        }
    }

    /// LFMR slope proxy: LFMR(max cores) − LFMR(1 core) on the host.
    pub fn lfmr_slope(&self) -> f64 {
        match (self.lfmr_by_cores.first(), self.lfmr_by_cores.last()) {
            (Some(a), Some(b)) => b - a,
            _ => 0.0,
        }
    }

    /// Mean LFMR across the sweep (the "level" feature).
    pub fn lfmr_mean(&self) -> f64 {
        if self.lfmr_by_cores.is_empty() {
            return self.lfmr;
        }
        self.lfmr_by_cores.iter().sum::<f64>() / self.lfmr_by_cores.len() as f64
    }
}

/// What to simulate for a profile.
#[derive(Debug, Clone)]
pub struct SweepOptions {
    pub core_models: &'static [CoreModel],
    /// Ordered list of system specs to sweep; the first is the
    /// normalization baseline ("host" for the paper presets).
    pub systems: Vec<SystemSpec>,
    pub scale: Scale,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions {
            core_models: &[CoreModel::OutOfOrder],
            systems: SystemSpec::default_sweep(),
            scale: Scale(1.0),
        }
    }
}

/// How the per-trace (system kind × core model) config-point fan-out
/// schedules its replays. Every mode produces byte-identical profiles
/// (`rust/tests/golden_profiles.rs` and `rust/tests/sim_properties.rs`
/// prove it): the shared [`TraceAnalysis`] is read-only during replay,
/// each config point simulates independently and deterministically, and
/// results are collected in grid order regardless of completion order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplayParallelism {
    /// Borrow whatever the global thread budget (`util::pool`) has to
    /// spare; degrades to serial when outer sweep workers hold it all.
    Auto,
    /// The serial reference path: the seed engine's nested config loop,
    /// kept for bench baselines and golden-snapshot regeneration.
    Serial,
    /// Exactly `n` extra worker lanes, bypassing the budget (tests).
    Extra(usize),
}

/// Simulate every (system, model, cores) point for one function, using
/// the global thread budget for the per-trace config fan-out.
pub fn profile_function(spec: &FunctionSpec, opt: SweepOptions) -> FunctionProfile {
    profile_function_tuned(spec, opt, ReplayParallelism::Auto)
}

/// [`profile_function`] with an explicit replay-scheduling mode.
pub fn profile_function_tuned(
    spec: &FunctionSpec,
    opt: SweepOptions,
    par: ReplayParallelism,
) -> FunctionProfile {
    assert!(
        !opt.systems.is_empty(),
        "SweepOptions.systems must contain at least one SystemSpec"
    );
    metrics::counter("sweep.functions_profiled").incr();
    let _span = telemetry::span_args(
        "profile",
        vec![("code".to_string(), Json::from(spec.id.code()))],
    );
    // Deterministic fault-injection boundary for the whole simulation of
    // one function (active only under DAMOV_FAULT_SPEC / test override).
    let fault_key = fault::key_of(&spec.id.code());
    fault::maybe_delay("sim", fault_key);
    fault::maybe_panic("sim", fault_key);
    fault::maybe_hang("sim", fault_key);
    let loc = locality(&spec.locality_trace(opt.scale));
    // The (model, system) grid in the exact order of the historical
    // serial nested loop, so `runs` keeps its byte-identical order under
    // parallel replay (par_map_extra preserves input order).
    let mut points: Vec<(CoreModel, usize)> =
        Vec::with_capacity(opt.core_models.len() * opt.systems.len());
    for &model in opt.core_models {
        for si in 0..opt.systems.len() {
            points.push((model, si));
        }
    }

    // Iterate core counts outermost so each trace is generated — and its
    // config-invariant analysis (SoA buffer, footprint, partitions,
    // reuse histogram) computed — exactly once, then shared read-only by
    // every config point.
    let mut runs = Vec::with_capacity(points.len() * CORE_SWEEP.len());
    for &cores in CORE_SWEEP.iter() {
        let trace = {
            let _gen = telemetry::span_args(
                "trace-gen",
                vec![
                    ("code".to_string(), Json::from(spec.id.code())),
                    ("cores".to_string(), Json::from(cores)),
                ],
            );
            spec.trace(cores, opt.scale)
        };
        let analysis = TraceAnalysis::new(&trace);
        // The SoA buffer is the only copy kept during replay.
        drop(trace);
        let replay_point = |&(model, si): &(CoreModel, usize)| -> SimResult {
            simulate_events(&opt.systems[si].build(cores, model), &analysis.events)
        };
        let results: Vec<SimResult> = match par {
            ReplayParallelism::Serial => points.iter().map(replay_point).collect(),
            ReplayParallelism::Auto => {
                let lease = pool::budget_acquire(points.len().saturating_sub(1));
                metrics::histogram("sweep.replay_lanes").record(lease.extra() as u64 + 1);
                pool::par_map_extra(&points, lease.extra(), replay_point)
            }
            ReplayParallelism::Extra(extra) => pool::par_map_extra(&points, extra, replay_point),
        };
        for (&(model, si), result) in points.iter().zip(results) {
            runs.push(Run {
                system: opt.systems[si].name.clone(),
                core_model: model,
                cores,
                result,
            });
        }
    }

    let base = opt.systems[0].name.as_str();
    let refrun = runs
        .iter()
        .find(|r| r.system == base && r.core_model == CoreModel::OutOfOrder && r.cores == 4)
        .or_else(|| runs.iter().find(|r| r.system == base && r.cores == 4))
        .expect("baseline@4 reference run");
    let lfmr_by_cores: Vec<f64> = CORE_SWEEP
        .iter()
        .filter_map(|&c| {
            runs.iter()
                .find(|r| {
                    r.system == base && r.core_model == opt.core_models[0] && r.cores == c
                })
                .map(|r| r.result.lfmr)
        })
        .collect();

    let profile = FunctionProfile {
        code: spec.id.code(),
        input: spec.id.input.clone(),
        suite: spec.id.suite.to_string(),
        paper_class: spec.paper_class,
        family_class: spec.family_class,
        representative: spec.representative,
        locality: loc,
        ai: refrun.result.ai,
        mpki: refrun.result.mpki,
        lfmr: refrun.result.lfmr,
        memory_bound: refrun.result.memory_bound,
        lfmr_by_cores,
        runs,
    };
    // Completed-profile counter, incremented only once the profile fully
    // exists — after every simulation and before the caller (and thus any
    // checkpoint-appending completion hook) can observe the profile. An
    // attempt that panics, is cancelled, or gets retried above never
    // reaches this line, so resume accounting stays exact under the
    // parallel replay path (see the [`PROFILE_CALLS`] contract).
    PROFILE_CALLS.fetch_add(1, Ordering::Relaxed);
    profile
}

/// A function whose profiling produced no result: it panicked on every
/// attempt, exceeded its wall-clock budget, or was cancelled.
#[derive(Debug, Clone)]
pub struct ProfileError {
    /// Function code (e.g. `STRTriad`) of the failed job.
    pub code: String,
    /// Index of the function in the input spec slice.
    pub index: usize,
    /// Attempts made (1 + retries; 0 = cancelled before starting).
    pub attempts: u32,
    /// How the job failed (panicked / timed-out / cancelled).
    pub kind: JobErrorKind,
    /// Stringified panic payload of the last attempt.
    pub message: String,
}

impl std::fmt::Display for ProfileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} (function #{}) {} after {} attempt(s): {}",
            self.code,
            self.index,
            self.kind.label(),
            self.attempts,
            self.message
        )
    }
}

/// Profile many functions in parallel with panic isolation and (when
/// configured in `pool`) deadline awareness: one panicking simulation
/// yields one recorded [`ProfileError`] (after `pool.max_retries`
/// bounded retries with backoff), a hung one is soft-cancelled at
/// `pool.job_timeout` and recorded as timed-out — never a lost sweep.
/// `on_complete` runs on the worker thread as soon as each profile
/// finishes — the coordinator uses it to append to the crash-safe
/// checkpoint so an interrupted sweep can resume. A cancelled job
/// unwinds before `on_complete`, so partial profiles never reach the
/// checkpoint. Sequencing per profile (single worker thread, so the
/// order is program order): simulate everything → increment
/// [`profile_call_count`] → run `on_complete` (checkpoint append). A
/// checkpoint record therefore implies its profile was already counted,
/// which is what makes the resume test's call-count arithmetic exact.
pub fn profile_all_checkpointed<C>(
    specs: &[FunctionSpec],
    opt: SweepOptions,
    pool: &PoolOptions,
    on_complete: C,
) -> Vec<Result<FunctionProfile, ProfileError>>
where
    C: Fn(&FunctionProfile) + Sync,
{
    par_map_catch_opts(specs, pool, |s| {
        let p = profile_function(s, opt.clone());
        on_complete(&p);
        p
    })
    .into_iter()
    .zip(specs)
    .map(|(res, spec)| {
        res.map_err(|e| ProfileError {
            code: spec.id.code(),
            index: e.index,
            attempts: e.attempts,
            kind: e.kind,
            message: e.message,
        })
    })
    .collect()
}

/// [`profile_all_checkpointed`] without a completion hook or deadlines.
pub fn profile_all_fallible(
    specs: &[FunctionSpec],
    opt: SweepOptions,
    threads: usize,
    max_retries: u32,
) -> Vec<Result<FunctionProfile, ProfileError>> {
    profile_all_checkpointed(specs, opt, &PoolOptions::new(threads, max_retries), |_| {})
}

/// Profile many functions in parallel. Panics (naming the function) if
/// any job fails — use [`profile_all_fallible`] to keep partial results.
pub fn profile_all(
    specs: &[FunctionSpec],
    opt: SweepOptions,
    threads: usize,
) -> Vec<FunctionProfile> {
    profile_all_fallible(specs, opt, threads, 0)
        .into_iter()
        .map(|r| r.unwrap_or_else(|e| panic!("sweep failed: {e}")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::registry;

    fn profile_at(code: &str, scale: f64) -> FunctionProfile {
        let spec = registry::by_code(code).unwrap();
        profile_function(
            &spec,
            SweepOptions {
                scale: Scale(scale),
                ..Default::default()
            },
        )
    }

    /// Class shapes are defined against the fixed Table-1 cache sizes, so
    /// shape assertions need full-size workloads.
    fn full_profile(code: &str) -> FunctionProfile {
        profile_at(code, 1.0)
    }

    fn tiny_profile(code: &str) -> FunctionProfile {
        profile_at(code, 0.1)
    }

    #[test]
    fn stream_profile_is_1a_shaped() {
        let p = full_profile("STRTriad");
        assert!(p.locality.temporal < 0.3);
        assert!(p.mpki > 10.0, "mpki={}", p.mpki);
        assert!(p.lfmr_mean() > 0.5, "lfmr={}", p.lfmr_mean());
        // NDP wins at high core counts.
        let s = p.ndp_speedup(CoreModel::OutOfOrder, 64);
        assert!(s > 1.2, "ndp speedup={s}");
    }

    #[test]
    fn compute_profile_is_2c_shaped() {
        let p = full_profile("PLY3mm");
        assert!(p.locality.temporal > 0.4, "temporal={}", p.locality.temporal);
        assert!(p.ai > 8.0, "ai={}", p.ai);
        let s = p.ndp_speedup(CoreModel::OutOfOrder, 4);
        assert!(s < 1.0, "ndp speedup={s}");
    }

    #[test]
    fn profile_contains_full_sweep() {
        let p = tiny_profile("CHAHsti");
        // 3 systems x 5 core counts.
        assert_eq!(p.runs.len(), 15);
        assert_eq!(p.lfmr_by_cores.len(), 5);
        assert!(p.run("ndp", CoreModel::OutOfOrder, 256).is_some());
    }

    #[test]
    fn norm_perf_baseline_is_one() {
        let p = tiny_profile("STRCpy");
        assert_eq!(p.baseline_system(), "host");
        let base = p.norm_perf("host", CoreModel::OutOfOrder, 1);
        assert!((base - 1.0).abs() < 1e-12);
    }
}
