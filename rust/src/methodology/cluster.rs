//! Clustering for Step 2 (Fig 3: K-means over spatial×temporal locality)
//! and §4.1 (Fig 19: hierarchical clustering over the five
//! classification features with Euclidean linkage).
//!
//! A pure-Rust implementation lives here (used by tests, reports and as
//! the `--no-artifacts` fallback); the k-means assignment step is also
//! compiled as a Pallas/JAX artifact and executed through PJRT by the
//! runtime — `runtime::analytics` cross-checks the two.

use crate::util::rng::Xoshiro256;
use crate::util::stats::euclidean;

/// K-means (Lloyd) with deterministic seeding. Returns (assignments,
/// centroids). Points are row vectors.
pub fn kmeans(points: &[Vec<f64>], k: usize, iters: usize, seed: u64) -> (Vec<usize>, Vec<Vec<f64>>) {
    assert!(!points.is_empty());
    let k = k.min(points.len()).max(1);
    let dims = points[0].len();
    let mut rng = Xoshiro256::new(seed);

    // k-means++-style greedy init: first centroid random, then farthest.
    let mut centroids: Vec<Vec<f64>> = Vec::with_capacity(k);
    centroids.push(points[rng.gen_usize(0, points.len())].clone());
    while centroids.len() < k {
        let far = points
            .iter()
            .max_by(|a, b| {
                let da = centroids.iter().map(|c| euclidean(a, c)).fold(f64::MAX, f64::min);
                let db = centroids.iter().map(|c| euclidean(b, c)).fold(f64::MAX, f64::min);
                da.partial_cmp(&db).unwrap()
            })
            .unwrap();
        centroids.push(far.clone());
    }

    let mut assign = vec![0usize; points.len()];
    for _ in 0..iters {
        // Assignment step (this is the step the Pallas artifact computes).
        let mut changed = false;
        for (i, p) in points.iter().enumerate() {
            let best = (0..k)
                .min_by(|&a, &b| {
                    euclidean(p, &centroids[a])
                        .partial_cmp(&euclidean(p, &centroids[b]))
                        .unwrap()
                })
                .unwrap();
            if assign[i] != best {
                assign[i] = best;
                changed = true;
            }
        }
        // Update step.
        let mut sums = vec![vec![0.0; dims]; k];
        let mut counts = vec![0usize; k];
        for (i, p) in points.iter().enumerate() {
            counts[assign[i]] += 1;
            for d in 0..dims {
                sums[assign[i]][d] += p[d];
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                for d in 0..dims {
                    centroids[c][d] = sums[c][d] / counts[c] as f64;
                }
            }
        }
        if !changed {
            break;
        }
    }
    (assign, centroids)
}

/// One k-means assignment step (the exact computation of the PJRT
/// artifact): nearest centroid per point.
pub fn kmeans_assign(points: &[Vec<f64>], centroids: &[Vec<f64>]) -> Vec<usize> {
    points
        .iter()
        .map(|p| {
            (0..centroids.len())
                .min_by(|&a, &b| {
                    euclidean(p, &centroids[a])
                        .partial_cmp(&euclidean(p, &centroids[b]))
                        .unwrap()
                })
                .unwrap()
        })
        .collect()
}

/// A merge step in the agglomerative dendrogram: clusters `a` and `b`
/// (node ids; leaves are 0..n, internal nodes continue upward) merge at
/// `distance` into node `id`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Merge {
    pub id: usize,
    pub a: usize,
    pub b: usize,
    pub distance: f64,
    pub size: usize,
}

/// Average-linkage agglomerative clustering (as Fig 19). Returns the
/// n−1 merges in order of increasing linkage distance.
pub fn hierarchical(points: &[Vec<f64>]) -> Vec<Merge> {
    let n = points.len();
    if n < 2 {
        return Vec::new();
    }
    // Active clusters: (node id, member point indices).
    let mut clusters: Vec<(usize, Vec<usize>)> = (0..n).map(|i| (i, vec![i])).collect();
    let mut merges = Vec::with_capacity(n - 1);
    let mut next_id = n;
    while clusters.len() > 1 {
        // Find the closest pair by average linkage.
        let mut best = (0usize, 1usize, f64::MAX);
        for i in 0..clusters.len() {
            for j in (i + 1)..clusters.len() {
                let mut sum = 0.0;
                for &p in &clusters[i].1 {
                    for &q in &clusters[j].1 {
                        sum += euclidean(&points[p], &points[q]);
                    }
                }
                let d = sum / (clusters[i].1.len() * clusters[j].1.len()) as f64;
                if d < best.2 {
                    best = (i, j, d);
                }
            }
        }
        let (i, j, d) = best;
        let (id_b, members_b) = clusters.remove(j);
        let (id_a, members_a) = clusters.remove(i);
        let mut members = members_a;
        members.extend(members_b);
        merges.push(Merge {
            id: next_id,
            a: id_a,
            b: id_b,
            distance: d,
            size: members.len(),
        });
        clusters.push((next_id, members));
        next_id += 1;
    }
    merges
}

/// Render a text dendrogram (Fig 19) with leaf labels.
pub fn render_dendrogram(labels: &[String], merges: &[Merge]) -> String {
    let n = labels.len();
    // Reconstruct member lists per node id.
    let mut members: Vec<Vec<usize>> = (0..n).map(|i| vec![i]).collect();
    for m in merges {
        let mut v = members[m.a].clone();
        v.extend(members[m.b].clone());
        members.push(v);
    }
    let mut out = String::new();
    for m in merges {
        let list = |node: usize| -> String {
            let mut ls: Vec<&str> = members[node].iter().map(|&i| labels[i].as_str()).collect();
            ls.sort_unstable();
            if ls.len() > 6 {
                format!("[{} … +{}]", ls[..6].join(", "), ls.len() - 6)
            } else {
                format!("[{}]", ls.join(", "))
            }
        };
        out.push_str(&format!(
            "d={:6.3}  {} + {}\n",
            m.distance,
            list(m.a),
            list(m.b)
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_blobs() -> Vec<Vec<f64>> {
        let mut pts = Vec::new();
        let mut rng = Xoshiro256::new(7);
        for _ in 0..20 {
            pts.push(vec![rng.gen_f64() * 0.1, rng.gen_f64() * 0.1]);
        }
        for _ in 0..20 {
            pts.push(vec![0.9 + rng.gen_f64() * 0.1, 0.9 + rng.gen_f64() * 0.1]);
        }
        pts
    }

    #[test]
    fn kmeans_separates_blobs() {
        let pts = two_blobs();
        let (assign, centroids) = kmeans(&pts, 2, 50, 1);
        assert_eq!(centroids.len(), 2);
        // All of the first 20 share a label; all of the last 20 the other.
        assert!(assign[..20].iter().all(|&a| a == assign[0]));
        assert!(assign[20..].iter().all(|&a| a == assign[20]));
        assert_ne!(assign[0], assign[20]);
    }

    #[test]
    fn kmeans_deterministic() {
        let pts = two_blobs();
        assert_eq!(kmeans(&pts, 2, 50, 9).0, kmeans(&pts, 2, 50, 9).0);
    }

    #[test]
    fn assign_matches_full_kmeans_fixedpoint() {
        let pts = two_blobs();
        let (assign, centroids) = kmeans(&pts, 2, 50, 1);
        assert_eq!(kmeans_assign(&pts, &centroids), assign);
    }

    #[test]
    fn hierarchical_merges_blobs_last() {
        let pts = two_blobs();
        let merges = hierarchical(&pts);
        assert_eq!(merges.len(), pts.len() - 1);
        // The final merge bridges the two blobs: by far the largest gap.
        let last = merges.last().unwrap();
        let prev = &merges[merges.len() - 2];
        assert!(last.distance > 3.0 * prev.distance, "last={} prev={}", last.distance, prev.distance);
        assert_eq!(last.size, pts.len());
        // Distances non-decreasing-ish (average linkage is monotone here).
        for w in merges.windows(2) {
            assert!(w[1].distance >= w[0].distance - 1e-9);
        }
    }

    #[test]
    fn dendrogram_renders_all_merges() {
        let pts = vec![vec![0.0], vec![0.1], vec![5.0]];
        let merges = hierarchical(&pts);
        let labels = vec!["a".to_string(), "b".to_string(), "c".to_string()];
        let txt = render_dendrogram(&labels, &merges);
        assert_eq!(txt.lines().count(), 2);
        assert!(txt.contains("[a]") || txt.contains("[a, b]"));
    }

    #[test]
    fn kmeans_k_larger_than_points() {
        let pts = vec![vec![0.0], vec![1.0]];
        let (assign, centroids) = kmeans(&pts, 5, 10, 3);
        assert_eq!(centroids.len(), 2);
        assert_eq!(assign.len(), 2);
    }
}
