//! # DAMOV reproduction library
//!
//! A from-scratch reproduction of *"DAMOV: A New Methodology and Benchmark
//! Suite for Evaluating Data Movement Bottlenecks"* (Oliveira et al., 2021)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * [`sim`] — the DAMOV-SIM substrate (caches, MSHRs, stream prefetcher,
//!   HMC DRAM with vault/bank/row-buffer model, NoC/NUCA, energy, core
//!   timing for out-of-order and in-order cores).
//! * [`workloads`] — the benchmark suite: deterministic trace generators
//!   reproducing the access patterns of the paper's 44 representative
//!   functions (plus input variants for the 144-function validation set).
//! * [`methodology`] — the paper's contribution: the three-step
//!   characterization pipeline (memory-bound identification, locality
//!   clustering, scalability-based bottleneck classification) and the
//!   six-class model.
//! * [`runtime`] — PJRT loading/execution of the AOT-compiled JAX/Pallas
//!   analytics artifacts (locality metrics, k-means) produced by
//!   `python/compile/aot.py`. Compiled only with `--features pjrt`; the
//!   default build degrades gracefully to the bit-compatible native Rust
//!   analytics.
//! * [`coordinator`] — parallel experiment scheduler, results store, and
//!   the report harness that regenerates every paper table and figure.
//! * [`util`] — in-repo infrastructure substrates (PRNG, JSON, CLI,
//!   thread pool, stats, property-testing harness, fault injection).
//!
//! ## Fault tolerance
//!
//! The hours-long characterization sweep is engineered to survive
//! failure: workers are panic-isolated with bounded retry
//! ([`util::pool::par_map_catch`]), every completed profile is appended
//! to a checksummed crash-safe checkpoint that `--resume` replays
//! ([`coordinator::store`]), caches are fingerprint-keyed so stale data
//! is never served ([`coordinator::sweep_fingerprint`]), and a
//! deterministic fault-injection harness ([`util::fault`], activated by
//! `DAMOV_FAULT_SPEC`) proves in CI that a sweep under injected panics
//! and I/O errors converges to byte-identical results.

pub mod coordinator;
pub mod methodology;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workloads;
