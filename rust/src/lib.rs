//! # DAMOV reproduction library
//!
//! A from-scratch reproduction of *"DAMOV: A New Methodology and Benchmark
//! Suite for Evaluating Data Movement Bottlenecks"* (Oliveira et al., 2021)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * [`sim`] — the DAMOV-SIM substrate (caches, MSHRs, stream prefetcher,
//!   HMC DRAM with vault/bank/row-buffer model, NoC/NUCA, energy, core
//!   timing for out-of-order and in-order cores).
//! * [`workloads`] — the benchmark suite: deterministic trace generators
//!   reproducing the access patterns of the paper's 44 representative
//!   functions (plus input variants for the 144-function validation set).
//! * [`methodology`] — the paper's contribution: the three-step
//!   characterization pipeline (memory-bound identification, locality
//!   clustering, scalability-based bottleneck classification) and the
//!   six-class model.
//! * [`runtime`] — PJRT loading/execution of the AOT-compiled JAX/Pallas
//!   analytics artifacts (locality metrics, k-means) produced by
//!   `python/compile/aot.py`.
//! * [`coordinator`] — parallel experiment scheduler, results store, and
//!   the report harness that regenerates every paper table and figure.
//! * [`util`] — in-repo infrastructure substrates (PRNG, JSON, CLI,
//!   thread pool, stats, property-testing harness).

pub mod coordinator;
pub mod methodology;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workloads;
