//! High-level analytics over the PJRT artifacts: locality metrics and
//! k-means, with the pure-Rust implementations as cross-check oracles.
//!
//! Shapes are fixed at AOT time (python/compile/model.py):
//! * locality: (4096, 32) f64 windows + (4096,) f64 mask →
//!   (spatial_sum, temporal_sum, n_valid) f64 scalars. Longer traces are
//!   streamed through in chunks; the tail is zero-padded and masked out.
//! * kmeans: (64, 8) f32 points + (8, 8) f32 centroids + (64,) f32 mask
//!   → ((64,) i32 assignments, (8, 8) f32 new centroids). Rust iterates
//!   Lloyd steps to a fixed point.

use super::artifact::{Artifact, PjrtContext};
use crate::methodology::locality::{LocalityMetrics, WINDOW};
use anyhow::{Context, Result};
use std::path::Path;

pub const CHUNK_WINDOWS: usize = 4096;
pub const KM_POINTS: usize = 64;
pub const KM_CENTROIDS: usize = 8;
pub const KM_FEATURES: usize = 8;

pub struct Analytics {
    #[allow(dead_code)]
    ctx: PjrtContext,
    locality: Artifact,
    kmeans: Artifact,
}

impl Analytics {
    pub fn load(dir: &Path) -> Result<Analytics> {
        let ctx = PjrtContext::cpu()?;
        let locality = Artifact::load(&ctx, dir, "locality")?;
        let kmeans = Artifact::load(&ctx, dir, "kmeans")?;
        Ok(Analytics {
            ctx,
            locality,
            kmeans,
        })
    }

    /// Locality metrics of a word-address stream via the Pallas artifact.
    pub fn locality_of_words(&self, words: &[u64]) -> Result<LocalityMetrics> {
        let n_windows = words.len() / WINDOW;
        if n_windows == 0 {
            return Ok(LocalityMetrics {
                spatial: 0.0,
                temporal: 0.0,
                windows: 0,
            });
        }
        let mut spatial_sum = 0.0;
        let mut temporal_sum = 0.0;
        let mut done = 0usize;
        while done < n_windows {
            let take = (n_windows - done).min(CHUNK_WINDOWS);
            let mut buf = vec![0.0f64; CHUNK_WINDOWS * WINDOW];
            let mut mask = vec![0.0f64; CHUNK_WINDOWS];
            for w in 0..take {
                mask[w] = 1.0;
                for k in 0..WINDOW {
                    buf[w * WINDOW + k] = words[(done + w) * WINDOW + k] as f64;
                }
            }
            let windows_lit = xla::Literal::vec1(&buf)
                .reshape(&[CHUNK_WINDOWS as i64, WINDOW as i64])
                .context("reshaping window literal")?;
            let mask_lit = xla::Literal::vec1(&mask);
            let out = self.locality.run(&[windows_lit, mask_lit])?;
            anyhow::ensure!(out.len() == 3, "locality artifact returned {}", out.len());
            spatial_sum += out[0].to_vec::<f64>()?[0];
            temporal_sum += out[1].to_vec::<f64>()?[0];
            done += take;
        }
        Ok(LocalityMetrics {
            spatial: (spatial_sum / n_windows as f64).min(1.0),
            temporal: (temporal_sum / (n_windows * WINDOW) as f64).min(1.0),
            windows: n_windows,
        })
    }

    /// Locality metrics of an access trace.
    pub fn locality(&self, trace: &[crate::sim::Access]) -> Result<LocalityMetrics> {
        self.locality_of_words(&crate::methodology::locality::word_trace(trace))
    }

    /// One k-means Lloyd iteration through the artifact. `points` is
    /// (n ≤ 64) × (f ≤ 8); extra slots are masked out / zero-padded.
    pub fn kmeans_step(
        &self,
        points: &[Vec<f64>],
        centroids: &[Vec<f64>],
    ) -> Result<(Vec<usize>, Vec<Vec<f64>>)> {
        let n = points.len();
        let k = centroids.len();
        anyhow::ensure!(n <= KM_POINTS, "too many points: {n}");
        anyhow::ensure!(k <= KM_CENTROIDS, "too many centroids: {k}");
        let f = points.first().map(|p| p.len()).unwrap_or(0);
        anyhow::ensure!(f <= KM_FEATURES, "too many features: {f}");

        let mut pts = vec![0.0f32; KM_POINTS * KM_FEATURES];
        let mut mask = vec![0.0f32; KM_POINTS];
        for (i, p) in points.iter().enumerate() {
            mask[i] = 1.0;
            for (d, &v) in p.iter().enumerate() {
                pts[i * KM_FEATURES + d] = v as f32;
            }
        }
        let mut cent = vec![0.0f32; KM_CENTROIDS * KM_FEATURES];
        for (c, row) in centroids.iter().enumerate() {
            for (d, &v) in row.iter().enumerate() {
                cent[c * KM_FEATURES + d] = v as f32;
            }
        }
        // Park unused centroid slots far away so no point selects them.
        for c in k..KM_CENTROIDS {
            for d in 0..KM_FEATURES {
                cent[c * KM_FEATURES + d] = 1.0e9;
            }
        }
        let pts_lit = xla::Literal::vec1(&pts)
            .reshape(&[KM_POINTS as i64, KM_FEATURES as i64])
            .context("points literal")?;
        let cent_lit = xla::Literal::vec1(&cent)
            .reshape(&[KM_CENTROIDS as i64, KM_FEATURES as i64])
            .context("centroid literal")?;
        let mask_lit = xla::Literal::vec1(&mask);
        let out = self.kmeans.run(&[pts_lit, cent_lit, mask_lit])?;
        anyhow::ensure!(out.len() == 2, "kmeans artifact returned {}", out.len());
        let assign_raw = out[0].to_vec::<i32>()?;
        let cent_raw = out[1].to_vec::<f32>()?;
        let assign = assign_raw[..n].iter().map(|&a| a as usize).collect();
        let new_centroids = (0..k)
            .map(|c| {
                (0..f)
                    .map(|d| cent_raw[c * KM_FEATURES + d] as f64)
                    .collect()
            })
            .collect();
        Ok((assign, new_centroids))
    }

    /// Full k-means via repeated artifact iterations, seeded identically
    /// to `methodology::cluster::kmeans` (so results cross-check).
    pub fn kmeans(
        &self,
        points: &[Vec<f64>],
        k: usize,
        iters: usize,
        seed: u64,
    ) -> Result<(Vec<usize>, Vec<Vec<f64>>)> {
        // Reuse the Rust initializer for identical seeding, then drive
        // iterations through PJRT.
        let (_, mut centroids) = crate::methodology::cluster::kmeans(points, k, 0, seed);
        let mut assign = vec![0usize; points.len()];
        for _ in 0..iters {
            let (a, c) = self.kmeans_step(points, &centroids)?;
            let done = a == assign;
            assign = a;
            centroids = c;
            if done {
                break;
            }
        }
        Ok((assign, centroids))
    }
}
