//! Layer-3 ↔ Layer-1/2 bridge: load the AOT-compiled JAX/Pallas analytics
//! artifacts (HLO text, produced by `python/compile/aot.py`) onto the
//! PJRT CPU client and execute them from Rust. Python never runs at
//! analysis time — the artifacts are self-contained.
//!
//! ## Graceful degradation
//!
//! The PJRT path is an acceleration, not a dependency: every analytics
//! entry point has a bit-compatible native Rust oracle
//! (`methodology::locality`, `methodology::cluster`). When the bridge is
//! unavailable — crate built without `--features pjrt`, artifacts not
//! compiled, or a load/execute failure (including injected `pjrt-load`
//! faults) — callers emit a structured [`degraded`] warning and fall
//! back to the native path instead of aborting.

#[cfg(feature = "pjrt")]
pub mod analytics;
pub mod artifact;

#[cfg(feature = "pjrt")]
pub use analytics::Analytics;
pub use artifact::{artifacts_available, default_artifact_dir};
#[cfg(feature = "pjrt")]
pub use artifact::{Artifact, PjrtContext};

/// Emit a structured degradation warning: machine-grepable `key=value`
/// fields naming the failed component, the fallback taken, and why.
/// Routed through `util::telemetry` (text rendering keeps the legacy
/// `warning: [degraded] ...` stderr format) and counted in the
/// `runtime.degradations` metric.
pub fn degraded(component: &str, fallback: &str, detail: impl std::fmt::Display) {
    use crate::util::json::Json;
    use crate::util::telemetry;
    telemetry::metrics::counter("runtime.degradations").incr();
    telemetry::warn(
        "degraded",
        &[
            ("component", Json::from(component)),
            ("fallback", Json::from(fallback)),
            ("detail", Json::from(detail.to_string())),
        ],
    );
}

/// Error produced by the stub runtime when the crate is built without
/// the `pjrt` feature (the offline environment has no `xla` crate).
#[cfg(not(feature = "pjrt"))]
#[derive(Debug, Clone)]
pub struct RuntimeUnavailable(pub String);

#[cfg(not(feature = "pjrt"))]
impl std::fmt::Display for RuntimeUnavailable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "PJRT runtime unavailable: {}", self.0)
    }
}

#[cfg(not(feature = "pjrt"))]
impl std::error::Error for RuntimeUnavailable {}

/// Stub analytics bridge compiled when the `pjrt` feature is off. Its
/// surface mirrors `analytics::Analytics`, but `load` always fails with
/// a structured error, which drives every caller onto the native Rust
/// fallback path (same numbers, no PJRT).
#[cfg(not(feature = "pjrt"))]
pub mod analytics {
    use super::RuntimeUnavailable;
    use crate::methodology::locality::LocalityMetrics;
    use crate::sim::Access;
    use std::path::Path;

    pub struct Analytics;

    impl Analytics {
        fn unavailable() -> RuntimeUnavailable {
            RuntimeUnavailable(
                "built without the `pjrt` feature; using the native Rust analytics".to_string(),
            )
        }

        pub fn load(_dir: &Path) -> Result<Analytics, RuntimeUnavailable> {
            Err(Self::unavailable())
        }

        pub fn locality(&self, _trace: &[Access]) -> Result<LocalityMetrics, RuntimeUnavailable> {
            Err(Self::unavailable())
        }

        pub fn locality_of_words(
            &self,
            _words: &[u64],
        ) -> Result<LocalityMetrics, RuntimeUnavailable> {
            Err(Self::unavailable())
        }

        pub fn kmeans_step(
            &self,
            _points: &[Vec<f64>],
            _centroids: &[Vec<f64>],
        ) -> Result<(Vec<usize>, Vec<Vec<f64>>), RuntimeUnavailable> {
            Err(Self::unavailable())
        }

        pub fn kmeans(
            &self,
            _points: &[Vec<f64>],
            _k: usize,
            _iters: usize,
            _seed: u64,
        ) -> Result<(Vec<usize>, Vec<Vec<f64>>), RuntimeUnavailable> {
            Err(Self::unavailable())
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use analytics::Analytics;
