//! Layer-3 ↔ Layer-1/2 bridge: load the AOT-compiled JAX/Pallas analytics
//! artifacts (HLO text, produced by `python/compile/aot.py`) onto the
//! PJRT CPU client and execute them from Rust. Python never runs at
//! analysis time — the artifacts are self-contained.

pub mod analytics;
pub mod artifact;

pub use analytics::Analytics;
pub use artifact::{default_artifact_dir, Artifact, PjrtContext};
