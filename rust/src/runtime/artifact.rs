//! PJRT artifact loading (adapted from /opt/xla-example/load_hlo).
//!
//! Interchange format is HLO **text**: jax ≥ 0.5 serializes protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see DESIGN.md and the xla-example README).
//!
//! The PJRT client itself (and its `xla`/`anyhow` dependencies) is only
//! compiled with `--features pjrt`; the artifact *discovery* helpers
//! below are dependency-free so every build can decide whether a
//! fallback to the native Rust analytics is needed.

use std::path::PathBuf;

/// Default artifact directory: `$DAMOV_ARTIFACTS` or `artifacts/` under
/// the workspace root (next to Cargo.toml), falling back to ./artifacts.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(d) = std::env::var("DAMOV_ARTIFACTS") {
        return PathBuf::from(d);
    }
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if manifest.exists() {
        return manifest;
    }
    PathBuf::from("artifacts")
}

/// True if the AOT artifacts have been built.
pub fn artifacts_available() -> bool {
    default_artifact_dir().join("locality.hlo.txt").exists()
}

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use crate::util::fault;
    use anyhow::{Context, Result};
    use std::path::Path;

    /// Shared PJRT CPU client. Creating a client is expensive; one per
    /// process is plenty.
    pub struct PjrtContext {
        pub client: xla::PjRtClient,
    }

    impl PjrtContext {
        pub fn cpu() -> Result<PjrtContext> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(PjrtContext { client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }
    }

    /// One compiled executable loaded from an HLO-text artifact.
    pub struct Artifact {
        pub name: String,
        exe: xla::PjRtLoadedExecutable,
    }

    impl Artifact {
        /// Load and compile `<name>.hlo.txt` from `dir`.
        pub fn load(ctx: &PjrtContext, dir: &Path, name: &str) -> Result<Artifact> {
            // Deterministic fault-injection boundary: a failed artifact
            // load must degrade to the native Rust path, never abort.
            fault::maybe_io("pjrt-load", fault::key_of(name))
                .with_context(|| format!("loading artifact {name}"))?;
            let path = dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = ctx
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {name}"))?;
            Ok(Artifact {
                name: name.to_string(),
                exe,
            })
        }

        /// Execute with literal inputs; returns the flattened output tuple
        /// (aot.py lowers with `return_tuple=True`).
        pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
            let result = self
                .exe
                .execute::<xla::Literal>(inputs)
                .with_context(|| format!("executing artifact {}", self.name))?;
            let lit = result[0][0]
                .to_literal_sync()
                .context("fetching result literal")?;
            Ok(lit.to_tuple()?)
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::{Artifact, PjrtContext};
