//! Benchmarks (criterion is unavailable offline; this is a small
//! warmup+median harness with the same discipline). Run `cargo bench`.
//!
//! Two groups:
//! * **hot paths** — replay throughput, trace generation, locality
//!   analytics (Rust and PJRT) — the §Perf optimization targets;
//! * **paper harness** — time to regenerate one representative figure
//!   of each family end-to-end (the `damov report` machinery).

use damov::methodology::locality;
use damov::methodology::step3::{
    profile_function, profile_function_tuned, ReplayParallelism, SweepOptions,
};
use damov::runtime::{artifact, Analytics};
use damov::sim::{simulate, simulate_events, CoreModel, SoaTrace, SystemConfig};
use damov::workloads::{registry, Scale};
use std::time::Instant;

struct Bench {
    name: &'static str,
    /// (seconds per iteration, optional units processed per iteration)
    run: Box<dyn FnMut() -> Option<f64>>,
}

fn time_it<F: FnMut() -> Option<f64>>(mut f: F, min_iters: usize) -> (f64, Option<f64>) {
    // Warmup.
    let mut units = f();
    let mut samples = Vec::new();
    for _ in 0..min_iters {
        let t0 = Instant::now();
        units = f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (samples[samples.len() / 2], units)
}

fn main() {
    let mut benches: Vec<Bench> = Vec::new();

    // --- hot paths ---
    let spec = registry::by_code("STRTriad").unwrap();
    let trace = spec.trace(4, Scale::full());
    let n_acc: f64 = trace.iter().map(Vec::len).sum::<usize>() as f64;
    let cfg = SystemConfig::host(4, CoreModel::OutOfOrder);
    benches.push(Bench {
        name: "replay/stream_host_4c",
        run: Box::new(move || {
            let r = simulate(&cfg, &trace);
            std::hint::black_box(r.time_s);
            Some(n_acc)
        }),
    });

    let gspec = registry::by_code("LIGPrkEmd").unwrap();
    let gtrace = gspec.trace(4, Scale::full());
    let gn: f64 = gtrace.iter().map(Vec::len).sum::<usize>() as f64;
    let gcfg = SystemConfig::host(4, CoreModel::OutOfOrder);
    benches.push(Bench {
        name: "replay/graph_host_4c",
        run: Box::new(move || {
            let r = simulate(&gcfg, &gtrace);
            std::hint::black_box(r.time_s);
            Some(gn)
        }),
    });

    let nspec = registry::by_code("PLYGramSch").unwrap();
    let ntrace = nspec.trace(64, Scale::full());
    let nn: f64 = ntrace.iter().map(Vec::len).sum::<usize>() as f64;
    let ncfg = SystemConfig::ndp(64, CoreModel::OutOfOrder);
    benches.push(Bench {
        name: "replay/contention_ndp_64c",
        run: Box::new(move || {
            let r = simulate(&ncfg, &ntrace);
            std::hint::black_box(r.time_s);
            Some(nn)
        }),
    });

    // Same workload as replay/stream_host_4c, but replayed from a
    // pre-built SoA buffer — isolates the column-layout win plus the
    // saved per-call transposition (the memoized sweep path).
    let sspec = registry::by_code("STRTriad").unwrap();
    let soa = SoaTrace::from_trace(&sspec.trace(4, Scale::full()));
    let sn = soa.total_accesses() as f64;
    let scfg = SystemConfig::host(4, CoreModel::OutOfOrder);
    benches.push(Bench {
        name: "replay/stream_host_4c_soa_shared",
        run: Box::new(move || {
            let r = simulate_events(&scfg, &soa);
            std::hint::black_box(r.time_s);
            Some(sn)
        }),
    });

    let tspec = registry::by_code("LIGPrkEmd").unwrap();
    benches.push(Bench {
        name: "tracegen/graph_64c",
        run: Box::new(move || {
            let t = tspec.trace(64, Scale::full());
            let n: usize = t.iter().map(Vec::len).sum();
            std::hint::black_box(&t);
            Some(n as f64)
        }),
    });

    let lspec = registry::by_code("STRTriad").unwrap();
    let ltrace = lspec.locality_trace(Scale::full());
    let lwords = locality::word_trace(&ltrace);
    let lw2 = lwords.clone();
    let ln = lwords.len() as f64;
    benches.push(Bench {
        name: "locality/rust",
        run: Box::new(move || {
            let m = locality::locality_of_words(&lw2);
            std::hint::black_box(m.spatial);
            Some(ln)
        }),
    });

    if artifact::artifacts_available() {
        match Analytics::load(&artifact::default_artifact_dir()) {
            Ok(an) => {
                let lw3 = lwords.clone();
                benches.push(Bench {
                    name: "locality/pjrt_artifact",
                    run: Box::new(move || {
                        let m = an.locality_of_words(&lw3).expect("pjrt");
                        std::hint::black_box(m.spatial);
                        Some(ln)
                    }),
                });
            }
            Err(e) => damov::runtime::degraded("pjrt-load", "skip-bench", e),
        }
    } else {
        eprintln!("[bench] artifacts not built; skipping locality/pjrt_artifact");
    }

    // --- paper harness (one figure per family) ---
    let fspec = registry::by_code("CHAHsti").unwrap();
    benches.push(Bench {
        name: "harness/profile_one_function_full_sweep",
        run: Box::new(move || {
            let p = profile_function(
                &fspec,
                SweepOptions {
                    scale: Scale(0.5),
                    ..Default::default()
                },
            );
            std::hint::black_box(p.mpki);
            None
        }),
    });

    // The same sweep with serial config-point replay: the gap between
    // this and the entry above is the parallel fast path's win (`damov
    // bench` measures it over the whole suite; docs/performance.md).
    let fspec2 = registry::by_code("CHAHsti").unwrap();
    benches.push(Bench {
        name: "harness/profile_one_function_serial_replay",
        run: Box::new(move || {
            let p = profile_function_tuned(
                &fspec2,
                SweepOptions {
                    scale: Scale(0.5),
                    ..Default::default()
                },
                ReplayParallelism::Serial,
            );
            std::hint::black_box(p.mpki);
            None
        }),
    });

    println!("{:45} {:>12} {:>14}", "benchmark", "median", "throughput");
    println!("{}", "-".repeat(73));
    for b in benches.iter_mut() {
        let (median, units) = time_it(&mut b.run, 5);
        let thr = units
            .map(|u| format!("{:>10.1} M/s", u / median / 1e6))
            .unwrap_or_else(|| "-".to_string());
        println!("{:45} {:>10.2}ms {:>14}", b.name, median * 1e3, thr);
    }
}
