//! Property-based invariants for the simulation models and the SoA
//! replay fast path, on the `util::prop` harness (many seeded random
//! cases; failures print the reproducing seed).
//!
//! Four families:
//! * LRU stack property — with the set count fixed, a bigger cache
//!   (more ways) can never hit less on the same access sequence.
//! * DRAM accounting — every access is exactly one of row hit / miss /
//!   conflict, so the row-hit rate is always a true fraction in [0, 1].
//! * SoA transposition — lossless for arbitrary traces, the foundation
//!   of the replay fast path's byte-identity argument.
//! * Replay determinism — profile bytes are invariant under the replay
//!   schedule (serial, any fixed lane count, budget-driven `Auto`),
//!   i.e. under arbitrary config-point completion orders.

use damov::coordinator::store;
use damov::methodology::step3::{profile_function_tuned, ReplayParallelism, SweepOptions};
use damov::sim::cache::Cache;
use damov::sim::config::CacheConfig;
use damov::sim::dram::Dram;
use damov::sim::{Access, CoreModel, SoaTrace, SystemConfig, Trace};
use damov::util::prop;
use damov::workloads::{registry, Scale};

/// With sets fixed, growing the way count strictly grows every set's LRU
/// stack, so true-LRU hits are monotonically non-decreasing (the classic
/// stack property — Mattson et al.). This is the invariant behind the
/// sweep's premise that cache size separates the DAMOV classes.
#[test]
fn lru_cache_hits_monotone_in_ways_at_fixed_sets() {
    prop::check(40, |rng| {
        let sets = 1usize << rng.gen_usize(2, 6); // 4..32 sets
        let n = rng.gen_usize(200, 1200);
        // Footprint around the mid-size capacity so small configs thrash
        // and large ones mostly hit — both sides of the stack exercised.
        let lines = (sets * 12).max(8) as u64;
        let seq: Vec<(u64, bool)> = (0..n)
            .map(|_| (rng.gen_range(lines) * 64, rng.gen_bool(0.3)))
            .collect();
        let mut prev_hits = 0u64;
        for (i, ways) in [1usize, 2, 4, 8].into_iter().enumerate() {
            let cfg = CacheConfig {
                size_bytes: 64 * sets * ways,
                ways,
                line_bytes: 64,
                latency_cycles: 4,
                epj_hit: 1.0,
                epj_miss: 1.0,
            };
            let mut cache = Cache::new(&cfg);
            for &(addr, write) in &seq {
                cache.access(addr, write);
            }
            assert_eq!(cache.hits + cache.misses, n as u64);
            assert!(
                i == 0 || cache.hits >= prev_hits,
                "stack property violated: {} ways hit {} < smaller cache's {}",
                ways,
                cache.hits,
                prev_hits
            );
            prev_hits = cache.hits;
        }
    });
}

/// Every DRAM access lands in exactly one row-buffer outcome and one
/// vault, so the totals partition the access count and the row-hit rate
/// is a genuine fraction in [0, 1] for any address mix.
#[test]
fn dram_row_outcomes_partition_accesses() {
    prop::check(40, |rng| {
        let cfg = SystemConfig::host(1, CoreModel::OutOfOrder).dram;
        let mut dram = Dram::new(&cfg);
        let n = rng.gen_usize(100, 2000);
        // Mix streaming (row-hit friendly) and random far jumps
        // (miss/conflict friendly) so all three outcomes occur across
        // the case population.
        let mut addr = rng.next_u64() >> 20;
        for _ in 0..n {
            if rng.gen_bool(0.7) {
                addr = addr.wrapping_add(64);
            } else {
                addr = rng.next_u64() >> rng.gen_usize(8, 28);
            }
            dram.access(addr, rng.gen_bool(0.3));
        }
        let s = &dram.stats;
        assert_eq!(s.reads + s.writes, n as u64);
        assert_eq!(s.row_hits + s.row_misses + s.row_conflicts, n as u64);
        assert_eq!(s.vault_accesses.iter().sum::<u64>(), n as u64);
        let rate = s.row_hits as f64 / n as f64;
        assert!((0.0..=1.0).contains(&rate), "row-hit rate {rate} out of range");
    });
}

/// SoA transposition must be lossless for arbitrary traces — including
/// empty per-core streams and extreme field values — since replay
/// correctness is argued from `SoaTrace::get(i)` reconstructing the
/// exact access sequence.
#[test]
fn soa_roundtrip_preserves_arbitrary_traces() {
    prop::check(60, |rng| {
        let cores = rng.gen_usize(1, 6);
        let trace: Trace = (0..cores)
            .map(|_| {
                let n = rng.gen_usize(0, 200);
                (0..n)
                    .map(|_| Access {
                        addr: rng.next_u64() >> rng.gen_usize(0, 33),
                        write: rng.gen_bool(0.3),
                        dep: rng.gen_bool(0.2),
                        bb: rng.gen_range(256) as u8,
                        gap: rng.gen_range(1 << 16) as u16,
                        ops: rng.gen_range(1 << 16) as u16,
                    })
                    .collect()
            })
            .collect();
        let soa = SoaTrace::from_trace(&trace);
        assert_eq!(soa.cores(), cores);
        assert_eq!(soa.total_accesses(), trace.iter().map(Vec::len).sum::<usize>());
        assert_eq!(soa.to_trace(), trace);
    });
}

/// Profile bytes must not depend on how config-point replays are
/// scheduled: serial, any fixed lane count (lanes race, so completion
/// order is effectively shuffled every run), or whatever `Auto`'s budget
/// negotiation picks on this machine. Serialized-byte equality is the
/// same criterion the golden harness and the sweep cache use.
#[test]
fn replay_profile_bytes_invariant_under_lane_schedule() {
    let codes = ["STRTriad", "CHAHsti", "SPLLucb", "HSJNPO"];
    prop::check(6, |rng| {
        let code = codes[rng.gen_usize(0, codes.len())];
        let spec = registry::by_code(code).unwrap();
        let opt = SweepOptions {
            scale: Scale(0.02 + rng.gen_f64() * 0.04),
            ..Default::default()
        };
        let bytes = |par| {
            store::profile_to_json(&profile_function_tuned(&spec, opt.clone(), par))
                .to_string_compact()
        };
        let reference = bytes(ReplayParallelism::Serial);
        let extra = rng.gen_usize(1, 9);
        assert_eq!(
            reference,
            bytes(ReplayParallelism::Extra(extra)),
            "Extra({extra}) diverged from serial for {code}"
        );
        assert_eq!(
            reference,
            bytes(ReplayParallelism::Auto),
            "Auto diverged from serial for {code}"
        );
    });
}
