//! The results store must reject every kind of on-disk corruption
//! cleanly — returning `None` (so the coordinator recomputes) rather
//! than panicking or serving damaged data. No fault injection here;
//! corruption is produced by editing the files directly.

use damov::coordinator::store;
use damov::methodology::step3::{profile_function, FunctionProfile, SweepOptions};
use damov::util::json::Json;
use damov::workloads::{registry, Scale};
use std::path::PathBuf;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("damov-rob-{name}-{}.json", std::process::id()))
}

fn sample() -> Vec<FunctionProfile> {
    ["STRCpy", "CHAHsti"]
        .iter()
        .map(|c| {
            profile_function(
                &registry::by_code(c).unwrap(),
                SweepOptions {
                    scale: Scale(0.05),
                    ..Default::default()
                },
            )
        })
        .collect()
}

#[test]
fn garbage_bytes_are_rejected() {
    let path = tmp("garbage");
    std::fs::write(&path, b"\x00\xffnot json at all{{{").unwrap();
    assert!(store::load_profiles(&path).is_none());
    assert!(store::load_profiles_keyed(&path, "fp").is_none());
    std::fs::remove_file(&path).ok();
}

#[test]
fn empty_file_is_rejected() {
    let path = tmp("empty");
    std::fs::write(&path, "").unwrap();
    assert!(store::load_profiles(&path).is_none());
    assert!(store::load_profiles_keyed(&path, "fp").is_none());
    std::fs::remove_file(&path).ok();
}

#[test]
fn truncated_file_is_rejected() {
    let path = tmp("truncated");
    let profiles = sample();
    store::save_profiles_keyed(&path, &profiles, "fp-t").unwrap();
    assert!(store::load_profiles_keyed(&path, "fp-t").is_some());
    // Chop the file mid-record, as a crash during a non-atomic write
    // would have (the atomic writer exists precisely to prevent this
    // state; the loader must still survive it).
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::write(&path, &text[..text.len() / 2]).unwrap();
    assert!(store::load_profiles(&path).is_none());
    assert!(store::load_profiles_keyed(&path, "fp-t").is_none());
    std::fs::remove_file(&path).ok();
}

#[test]
fn wrong_schema_version_is_rejected() {
    let path = tmp("schema");
    let profiles = sample();
    store::save_profiles_keyed(&path, &profiles, "fp-s").unwrap();
    let mut j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    j.set("schema", 99.0);
    std::fs::write(&path, j.to_string_pretty()).unwrap();
    assert!(store::load_profiles(&path).is_none());
    assert!(store::load_profiles_keyed(&path, "fp-s").is_none());
    std::fs::remove_file(&path).ok();
}

#[test]
fn tampered_record_fails_its_checksum() {
    let path = tmp("tamper");
    let profiles = sample();
    store::save_profiles_keyed(&path, &profiles, "fp-c").unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    // Flip one value inside a stored profile without touching its
    // checksum: the record still parses, but canonical re-serialization
    // no longer matches the checksum, so the whole file is distrusted.
    assert!(text.contains("\"STRCpy\""));
    let tampered = text.replace("\"STRCpy\"", "\"STRXXX\"");
    std::fs::write(&path, tampered).unwrap();
    assert!(store::load_profiles(&path).is_none());
    assert!(store::load_profiles_keyed(&path, "fp-c").is_none());
    std::fs::remove_file(&path).ok();
}

#[test]
fn legacy_bare_array_loads_unkeyed_only() {
    let path = tmp("legacy");
    let profiles = sample();
    // Schema-v1 files were a bare array of profiles, no envelope.
    let legacy = Json::Arr(profiles.iter().map(store::profile_to_json).collect());
    std::fs::write(&path, legacy.to_string_pretty()).unwrap();
    let loaded = store::load_profiles(&path).expect("legacy files stay readable");
    assert_eq!(loaded.len(), profiles.len());
    assert_eq!(loaded[0].code, profiles[0].code);
    // ...but the fingerprint-checked loader refuses them, forcing one
    // clean recompute into the current format.
    assert!(store::load_profiles_keyed(&path, "").is_none());
    std::fs::remove_file(&path).ok();
}

#[test]
fn checkpoint_with_corrupt_header_is_empty() {
    let path = tmp("ckpt-hdr");
    std::fs::write(&path, "not-a-header\n").unwrap();
    assert!(store::load_checkpoint(&path, "fp").is_empty());
    // Header parses but carries the wrong schema → also empty.
    std::fs::write(&path, "{\"schema\":1,\"fingerprint\":\"fp\"}\n").unwrap();
    assert!(store::load_checkpoint(&path, "fp").is_empty());
    std::fs::remove_file(&path).ok();
}
