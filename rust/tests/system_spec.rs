//! Integration tests for the data-driven system-description layer:
//! JSON round-trips, strict rejection of malformed specs, fingerprint
//! canonicalization/discrimination, a custom 2-level spec swept
//! end-to-end through the coordinator cache, and the CLI's non-zero
//! exit codes on unknown commands, options, and report names.

use std::process::Command;

use damov::coordinator::{store, sweep_fingerprint, Coordinator};
use damov::methodology::step3::{profile_call_count, SweepOptions};
use damov::sim::{MemoryBackend, SpecError, SystemSpec};
use damov::util::prop;
use damov::util::rng::Xoshiro256;
use damov::workloads::{registry, Scale};

fn fixture_path() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/custom_2level.json")
}

// --- Round-trips ------------------------------------------------------

#[test]
fn preset_specs_roundtrip_through_json() {
    for spec in SystemSpec::presets() {
        let pretty = spec.to_json().to_string_pretty();
        let back = SystemSpec::from_json_str(&pretty)
            .unwrap_or_else(|e| panic!("{}: {e}", spec.name));
        assert_eq!(back, spec, "{} must round-trip", spec.name);
        assert_eq!(back.fingerprint(), spec.fingerprint());
        // Serialization is deterministic: serialize twice, same bytes.
        assert_eq!(spec.to_json().to_string_compact(), back.to_json().to_string_compact());
    }
}

/// Random well-formed spec via the builder (all-power-of-two geometry,
/// prefetcher only with a private L2, NUCA only with a shared level).
fn random_spec(rng: &mut Xoshiro256, tag: u64) -> SystemSpec {
    let mut b = SystemSpec::builder(&format!("rand-{tag}"))
        .private_cache(1 << rng.gen_usize(13, 18), 1 << rng.gen_usize(0, 4), 4, 15.0, 33.0);
    let has_l2 = rng.gen_bool(0.6);
    if has_l2 {
        b = b.private_cache(256 << 10, 8, 7, 46.0, 93.0);
    }
    let has_llc = rng.gen_bool(0.6);
    if has_llc {
        let banks = 1 << rng.gen_usize(2, 6);
        b = b.shared_cache(1 << rng.gen_usize(20, 24), 16, 27, 945.0, 1904.0, banks);
    }
    if has_l2 && rng.gen_bool(0.4) {
        b = b.prefetcher(rng.gen_usize(1, 32), rng.gen_usize(1, 8));
    }
    b = if has_llc && rng.gen_bool(0.3) {
        b.backend(MemoryBackend::NucaMesh)
    } else if rng.gen_bool(0.3) {
        b.backend(MemoryBackend::DirectVault)
    } else {
        b.backend(MemoryBackend::HmcLink)
    };
    b.read_only_l1(rng.gen_bool(0.3)).build().expect("random builder spec must validate")
}

#[test]
fn random_builder_specs_roundtrip_through_json() {
    prop::check(60, |rng| {
        let tag = rng.gen_range(1 << 32);
        let spec = random_spec(rng, tag);
        let back = SystemSpec::from_json_str(&spec.to_json().to_string_pretty()).unwrap();
        assert_eq!(back, spec);
        assert_eq!(back.fingerprint(), spec.fingerprint());
    });
}

// --- Rejection of malformed specs ------------------------------------

/// Minimal valid spec text the rejection cases mutate from.
const MINIMAL: &str = r#"{
  "name": "tiny",
  "caches": [
    {"size_bytes": 16384, "ways": 4, "latency_cycles": 3, "epj_hit": 12.0, "epj_miss": 28.0}
  ]
}"#;

#[test]
fn malformed_specs_are_rejected_with_structured_errors() {
    // The baseline parses — every case below is one deliberate break.
    SystemSpec::from_json_str(MINIMAL).expect("minimal spec must be valid");

    let err = |text: &str| SystemSpec::from_json_str(text).unwrap_err();

    assert!(matches!(err("not json {{{"), SpecError::Parse(_)));
    assert!(matches!(
        err(r#"{"name":"x","caches":[],"frobnicate":1}"#),
        SpecError::UnknownField(_)
    ));
    assert!(matches!(
        err(&MINIMAL.replace("\"size_bytes\"", "\"size_byts\"")),
        SpecError::UnknownField(_) | SpecError::MissingField(_)
    ));
    let nameless = MINIMAL.replace("  \"name\": \"tiny\",\n", "");
    assert!(matches!(err(&nameless), SpecError::MissingField(_)));
    assert!(matches!(err(r#"{"name":"x"}"#), SpecError::MissingField(_)));
    assert!(matches!(err(r#"{"name":"x","caches":[]}"#), SpecError::EmptyHierarchy));
    assert!(matches!(
        err(&MINIMAL.replace("\"tiny\"", "\"bad name!\"")),
        SpecError::BadName(_)
    ));
    assert!(matches!(
        err(&MINIMAL.replace("{\n  \"name\"", "{\n  \"backend\": \"warp-drive\",\n  \"name\"")),
        SpecError::BadValue(_)
    ));
    // Prefetcher with no private L2 to sit at.
    let pf = "{\n  \"prefetcher\": {\"streams\": 4, \"degree\": 2},\n  \"name\"";
    assert!(matches!(
        err(&MINIMAL.replace("{\n  \"name\"", pf)),
        SpecError::Hierarchy(_)
    ));
    // NUCA backend with no shared level.
    assert!(matches!(
        err(&MINIMAL.replace("{\n  \"name\"", "{\n  \"backend\": \"nuca-mesh\",\n  \"name\"")),
        SpecError::Hierarchy(_)
    ));
}

#[test]
fn degenerate_geometry_is_rejected_at_construction() {
    // sets = 4096 / 64 / 128 would divide to 0 — the class of geometry
    // that used to panic deep inside Cache::new at simulation time.
    let e = SystemSpec::builder("degenerate")
        .private_cache(4096, 128, 4, 1.0, 1.0)
        .build()
        .unwrap_err();
    assert!(matches!(e, SpecError::Geometry(_)), "got {e}");

    // Non-power-of-two set count (24576 / 64 / 4 = 96 sets).
    let e = SystemSpec::builder("np2")
        .private_cache(24576, 4, 4, 1.0, 1.0)
        .build()
        .unwrap_err();
    assert!(matches!(e, SpecError::Geometry(_)), "got {e}");

    // Size not divisible by line*ways.
    let e = SystemSpec::builder("ragged")
        .private_cache(1000, 4, 4, 1.0, 1.0)
        .build()
        .unwrap_err();
    assert!(matches!(e, SpecError::Geometry(_)), "got {e}");
}

// --- Fingerprints ------------------------------------------------------

#[test]
fn fingerprints_discriminate_and_canonicalize() {
    // Distinct specs — including near-identical ones — never collide.
    let mut variant = SystemSpec::host();
    variant.name = "host2".to_string();
    let mut bigger_l1 = SystemSpec::host();
    bigger_l1.caches[0].size_bytes *= 2;
    let all = [
        SystemSpec::host(),
        SystemSpec::host_prefetch(),
        SystemSpec::ndp(),
        SystemSpec::host_nuca(),
        SystemSpec::load(fixture_path().as_ref()).unwrap(),
        variant,
        bigger_l1,
    ];
    for (i, a) in all.iter().enumerate() {
        for b in &all[i + 1..] {
            assert_ne!(a.fingerprint(), b.fingerprint(), "{} vs {}", a.name, b.name);
        }
    }

    // A respelled-but-identical spec (defaults omitted, keys reordered,
    // different whitespace) canonicalizes to the same fingerprint...
    let respelled = r#"{
        "caches": [
            {"ways": 8, "size_bytes": 32768, "latency_cycles": 4, "epj_hit": 15.0, "epj_miss": 33.0},
            {"epj_miss": 93.0, "epj_hit": 46.0, "size_bytes": 262144, "ways": 8, "latency_cycles": 7},
            {"size_bytes": 8388608, "ways": 16, "latency_cycles": 27, "epj_hit": 945.0, "epj_miss": 1904.0, "shared": true}
        ],
        "name": "host"
    }"#;
    let re = SystemSpec::from_json_str(respelled).unwrap();
    assert_eq!(re, SystemSpec::host());
    assert_eq!(re.fingerprint(), SystemSpec::host().fingerprint());

    // ...so the sweep cache key is identical (a cache hit), while any
    // semantically different system set changes the key.
    let specs: Vec<_> = registry::representatives().into_iter().take(2).collect();
    let opt_canonical = SweepOptions {
        systems: vec![SystemSpec::host()],
        scale: Scale(0.05),
        ..Default::default()
    };
    let opt_respelled = SweepOptions {
        systems: vec![re],
        scale: Scale(0.05),
        ..Default::default()
    };
    let opt_different = SweepOptions {
        systems: vec![all[6].clone()],
        scale: Scale(0.05),
        ..Default::default()
    };
    assert_eq!(
        sweep_fingerprint(&specs, &opt_canonical),
        sweep_fingerprint(&specs, &opt_respelled)
    );
    assert_ne!(
        sweep_fingerprint(&specs, &opt_canonical),
        sweep_fingerprint(&specs, &opt_different)
    );
}

// --- End-to-end: custom spec through the coordinator -------------------

#[test]
fn custom_2level_spec_sweeps_end_to_end_and_caches() {
    let dir = std::env::temp_dir().join(format!("damov-spec-e2e-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let custom = SystemSpec::load(fixture_path().as_ref()).unwrap();
    assert_eq!(custom.name, "edge-2level");
    assert_eq!(custom.caches.len(), 2);

    let specs: Vec<_> = registry::representatives().into_iter().take(2).collect();
    let opt = SweepOptions {
        systems: vec![custom.clone()],
        scale: Scale(0.05),
        ..Default::default()
    };
    let profiles = Coordinator::new(&dir, 2).profiles("spec-e2e", &specs, opt.clone(), true);
    assert_eq!(profiles.len(), 2);
    for p in &profiles {
        assert_eq!(p.baseline_system(), "edge-2level");
        assert!(!p.runs.is_empty());
        assert!(p.runs.iter().all(|r| r.system == "edge-2level"));
        assert!(p.lfmr_by_cores.iter().all(|v| (0.0..=1.0 + 1e-9).contains(v)));
    }
    let bytes: Vec<String> = profiles
        .iter()
        .map(|p| store::profile_to_json(p).to_string_compact())
        .collect();

    // A respelled-identical spec must hit the same cache: zero profile
    // recomputation, byte-identical result set.
    let respelled = SystemSpec::from_json_str(&custom.to_json().to_string_pretty()).unwrap();
    let opt2 = SweepOptions {
        systems: vec![respelled],
        ..opt
    };
    let calls_before = profile_call_count();
    let cached = Coordinator::new(&dir, 2).profiles("spec-e2e", &specs, opt2, false);
    assert_eq!(profile_call_count(), calls_before, "cache hit must not recompute");
    let cached_bytes: Vec<String> = cached
        .iter()
        .map(|p| store::profile_to_json(p).to_string_compact())
        .collect();
    assert_eq!(bytes, cached_bytes);

    std::fs::remove_dir_all(&dir).ok();
}

// --- CLI exit codes (satellite bugfix) ---------------------------------

fn damov(cli: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_damov"))
        .args(cli)
        .output()
        .expect("spawn damov binary")
}

#[test]
fn cli_unknown_paths_exit_nonzero_with_hints() {
    let out = damov(&["frobnicate"]);
    assert_eq!(out.status.code(), Some(2));
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));

    let out = damov(&["report", "nosuchreport"]);
    assert_eq!(out.status.code(), Some(2), "unknown report must not exit 0");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown report"), "stderr: {err}");
    assert!(err.contains("known reports:"), "stderr must hint at valid names");

    let out = damov(&["report", "all", "--frobnicate"]);
    assert_eq!(out.status.code(), Some(2));
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown option"));

    let out = damov(&["list", "--threads", "4"]);
    assert_eq!(out.status.code(), Some(2), "options foreign to the command are errors");

    let out = damov(&["systems", "nosuch"]);
    assert_eq!(out.status.code(), Some(2));
}

#[test]
fn cli_systems_subcommand_lists_and_dumps() {
    let out = damov(&["systems"]);
    assert_eq!(out.status.code(), Some(0));
    let text = String::from_utf8_lossy(&out.stdout);
    for preset in ["host", "host+pf", "ndp", "host-nuca"] {
        assert!(text.contains(preset), "preset {preset} missing from listing");
    }

    let out = damov(&["systems", "ndp"]);
    assert_eq!(out.status.code(), Some(0));
    let dumped = String::from_utf8_lossy(&out.stdout);
    let spec = SystemSpec::from_json_str(&dumped).expect("dump must parse back");
    assert_eq!(spec, SystemSpec::ndp(), "dump must be the preset itself");
}
