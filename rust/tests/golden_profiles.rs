//! Golden-profile regression harness: pins the byte-exact serialized
//! profile of **every** registry workload at `Scale::tiny()`, and proves
//! the SoA/parallel replay fast path reproduces the serial reference
//! bit-for-bit.
//!
//! Three layers of protection:
//!
//! 1. `parallel == serial` is asserted in-process for all 144 functions,
//!    independent of any committed file — a scheduling or SoA bug fails
//!    here even on a machine that has never seen the golden file.
//! 2. The serialized lines are compared against the committed
//!    `tests/golden/profiles-tiny.jsonl`, so a *semantic* drift in the
//!    simulator (timing model, energy, locality, trace generators)
//!    cannot land silently: it shows up as a reviewable golden diff.
//! 3. Fixed-lane schedules (`Extra(k)`) are checked against serial on a
//!    spread of workloads, covering the scheduler paths `Auto` may not
//!    take on a small CI machine.
//!
//! Bootstrap / regeneration: if the golden file is missing, the test
//! writes it from the serial reference and passes (first run on a fresh
//! checkout commits the baseline). To intentionally update after a
//! semantic change, run with `DAMOV_GOLDEN_REGEN=1` and commit the diff.

use damov::coordinator::store;
use damov::methodology::step3::{profile_function_tuned, ReplayParallelism, SweepOptions};
use damov::util::pool::{default_threads, par_map};
use damov::workloads::{registry, FunctionSpec, Scale};
use std::path::PathBuf;

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/profiles-tiny.jsonl")
}

fn sweep_opt() -> SweepOptions {
    SweepOptions {
        scale: Scale::tiny(),
        ..Default::default()
    }
}

/// One canonical golden line: the compact-JSON serialization the sweep
/// cache and checkpoints use (`store::profile_to_json`), so the golden
/// file pins exactly the bytes persistence would write.
fn profile_line(spec: &FunctionSpec, par: ReplayParallelism) -> String {
    store::profile_to_json(&profile_function_tuned(spec, sweep_opt(), par)).to_string_compact()
}

fn header(functions: usize) -> String {
    format!(
        "{{\"golden\":\"profiles-tiny\",\"schema\":1,\"scale\":0.05,\"functions\":{functions}}}"
    )
}

#[test]
fn golden_profiles_parallel_matches_serial_and_committed_file() {
    let specs = registry::all_functions();
    let threads = default_threads();

    // Serial reference: the historical one-config-at-a-time nested loop.
    let serial: Vec<String> = par_map(&specs, threads, |s| {
        profile_line(s, ReplayParallelism::Serial)
    });
    // Production fast path: shared TraceAnalysis + budget-driven lanes.
    let parallel: Vec<String> = par_map(&specs, threads, |s| {
        profile_line(s, ReplayParallelism::Auto)
    });
    for ((spec, s), p) in specs.iter().zip(&serial).zip(&parallel) {
        assert_eq!(s, p, "parallel replay diverged from serial for {}", spec.id.code());
    }

    let mut lines = vec![header(specs.len())];
    lines.extend(serial);
    let contents = lines.join("\n") + "\n";

    let path = golden_path();
    let regen = std::env::var_os("DAMOV_GOLDEN_REGEN").is_some();
    if regen || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, &contents).unwrap();
        eprintln!(
            "golden: {} {} ({} profiles)",
            if regen { "regenerated" } else { "bootstrapped" },
            path.display(),
            specs.len()
        );
        return;
    }

    let committed = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        committed,
        contents,
        "serialized profiles drifted from {} — if the semantic change is \
         intentional, regenerate with DAMOV_GOLDEN_REGEN=1 and commit the diff",
        path.display()
    );
}

/// Fixed lane counts (including over-provisioned ones) must reproduce
/// the serial bytes too; `Auto` may never pick these on a busy or small
/// machine, so they get their own coverage on a class-diverse subset.
#[test]
fn golden_profiles_fixed_lane_counts_match_serial() {
    let codes = ["STRTriad", "CHAHsti", "PLYgemver", "HSJNPO", "RODNw"];
    for code in codes {
        let spec = registry::by_code(code).unwrap_or_else(|| panic!("unknown code {code}"));
        let reference = profile_line(&spec, ReplayParallelism::Serial);
        for extra in [1usize, 2, 7] {
            assert_eq!(
                reference,
                profile_line(&spec, ReplayParallelism::Extra(extra)),
                "Extra({extra}) replay diverged from serial for {code}"
            );
        }
    }
}
