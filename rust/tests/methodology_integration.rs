//! Integration: the full three-step methodology classifies canonical
//! suite functions into their paper classes using the calibrated
//! default thresholds (the same ones `damov characterize` applies).

use damov::methodology::classify::{self, Class, Features};
use damov::methodology::locality;
use damov::methodology::step3::{profile_function, SweepOptions};
use damov::workloads::{registry, Scale};

fn thresholds() -> classify::Thresholds {
    classify::Thresholds {
        temporal: 0.30,
        ai: 8.5,
        mpki: 45.0,
        lfmr: 0.56,
        slope_dec: -0.25,
        slope_inc: 0.25,
    }
}

fn classify_code(code: &str, scale: f64) -> (Class, Class) {
    let spec = registry::by_code(code).expect("function");
    let profile = profile_function(
        &spec,
        SweepOptions {
            scale: Scale(scale),
            ..Default::default()
        },
    );
    let loc = locality::locality(&spec.locality_trace(Scale(scale)));
    let mut feats = Features::of(&profile);
    feats.temporal = loc.temporal;
    let predicted = classify::classify(&feats, &thresholds());
    let expected = Class::parse(spec.family_class).unwrap();
    (predicted, expected)
}

#[test]
fn stream_classifies_as_1a() {
    let (p, e) = classify_code("STRTriad", 1.0);
    assert_eq!(p, e, "STRTriad should be 1a");
}

#[test]
fn pointer_chase_classifies_as_1b() {
    let (p, e) = classify_code("PLYalu", 1.0);
    assert_eq!(p, e, "PLYalu should be 1b");
}

#[test]
fn blocked_compute_classifies_as_2c() {
    let (p, e) = classify_code("PLY3mm", 1.0);
    assert_eq!(p, e, "PLY3mm should be 2c");
}

#[test]
fn contention_kernel_classifies_as_2a() {
    let (p, e) = classify_code("PLYGramSch", 1.0);
    assert_eq!(p, e, "PLYGramSch should be 2a");
}

#[test]
fn step1_filters_and_orders_memory_boundedness() {
    // A 1b chase must look *more* memory-bound than a 2c kernel.
    use damov::methodology::step1;
    let chase = step1::identify(&registry::by_code("PLYalu").unwrap(), Scale(0.5));
    let compute = step1::identify(&registry::by_code("PLY3mm").unwrap(), Scale(0.5));
    assert!(chase.selected && compute.selected);
    assert!(chase.memory_bound > compute.memory_bound);
}
