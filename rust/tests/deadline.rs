//! Deadline-aware sweep proofs (ISSUE acceptance criteria): the
//! hung-job watchdog fires deterministically, a sweep-wide deadline
//! drains the pool with every job accounted for, and a sweep that lost
//! functions to `--job-timeout` converges byte-identically to a clean
//! run after a fault-free `--resume`.
//!
//! The pool-level tests (`watchdog_*`, `sweep_deadline_*`,
//! `timeout_stress_*`) hang cooperatively — a `cancel::poll()` sleep
//! loop, exactly what `fault::maybe_hang` does — so they exercise the
//! real cancellation path without any fault spec. Only the end-to-end
//! test installs a (process-global) fault override; no other test in
//! this binary touches fault sites, so they may run concurrently.

use damov::coordinator::{store, sweep_fingerprint, Coordinator};
use damov::methodology::step3::{profile_call_count, FunctionProfile, SweepOptions};
use damov::util::cancel;
use damov::util::fault::{self, FaultSpec};
use damov::util::pool::{par_map_catch_opts, JobErrorKind, PoolOptions};
use damov::util::rng::mix64;
use damov::workloads::{registry, Scale};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

/// Hang until the watchdog cancels this job: the same cooperative loop
/// `fault::maybe_hang` runs, inlined so pool tests need no fault spec.
fn hang_until_cancelled() {
    loop {
        cancel::poll();
        std::thread::sleep(Duration::from_millis(1));
    }
}

#[test]
fn watchdog_cancels_hung_jobs_deterministically() {
    let items: Vec<usize> = (0..16).collect();
    let opts = PoolOptions {
        threads: 4,
        max_retries: 2,
        job_timeout: Some(Duration::from_millis(100)),
        sweep_deadline: None,
    };
    let results = par_map_catch_opts(&items, &opts, |&i| {
        if i % 8 == 3 {
            hang_until_cancelled();
        }
        i * 2
    });
    assert_eq!(results.len(), 16);
    for (i, r) in results.iter().enumerate() {
        if i % 8 == 3 {
            let e = r.as_ref().expect_err("hung job must not produce a value");
            assert_eq!(e.kind, JobErrorKind::TimedOut, "job {i}: {e}");
            assert_eq!(e.index, i, "error carries the job identity");
            assert_eq!(e.attempts, 1, "timed-out jobs are never retried in-sweep");
            assert!(e.to_string().contains("timed-out"), "{e}");
        } else {
            assert_eq!(*r.as_ref().unwrap(), i * 2, "job {i} completes normally");
        }
    }
}

#[test]
fn sweep_deadline_stops_the_pool_with_every_job_accounted_for() {
    // 64 jobs of >= 10 ms on 2 workers is >= 320 ms of serial work, so a
    // 150 ms sweep deadline is guaranteed to expire mid-sweep; and the
    // first jobs finish well inside it, so both outcomes are observed.
    let items: Vec<usize> = (0..64).collect();
    let opts = PoolOptions {
        threads: 2,
        max_retries: 0,
        job_timeout: None,
        sweep_deadline: Some(Duration::from_millis(150)),
    };
    let results = par_map_catch_opts(&items, &opts, |&i| {
        for _ in 0..10 {
            cancel::poll();
            std::thread::sleep(Duration::from_millis(1));
        }
        i
    });
    assert_eq!(results.len(), 64, "every input slot is filled");
    let mut done = 0usize;
    let mut cancelled = 0usize;
    for (i, r) in results.iter().enumerate() {
        match r {
            Ok(v) => {
                assert_eq!(*v, i);
                done += 1;
            }
            Err(e) => {
                assert_eq!(e.kind, JobErrorKind::Cancelled, "job {i}: {e}");
                assert_eq!(e.index, i);
                cancelled += 1;
            }
        }
    }
    assert!(done > 0, "jobs started before the deadline complete");
    assert!(cancelled > 0, "the deadline must cancel the rest");
    assert_eq!(done + cancelled, 64);
}

/// Satellite: concurrency stress — many workers, mixed hanging and fast
/// jobs. Input order is preserved, every non-timed-out job runs exactly
/// once, and timeouts land precisely on the hanging indices.
#[test]
fn timeout_stress_many_threads_mixed_jobs() {
    const N: usize = 300;
    let items: Vec<usize> = (0..N).collect();
    let opts = PoolOptions {
        threads: 16,
        max_retries: 3,
        job_timeout: Some(Duration::from_millis(80)),
        sweep_deadline: None,
    };
    let completed = AtomicUsize::new(0);
    let results = par_map_catch_opts(&items, &opts, |&x| {
        if x % 7 == 5 {
            hang_until_cancelled();
        }
        completed.fetch_add(1, Ordering::Relaxed);
        x * 2
    });
    assert_eq!(results.len(), N);
    let mut ok = 0usize;
    for (i, r) in results.iter().enumerate() {
        if i % 7 == 5 {
            let e = r.as_ref().expect_err("hung job must time out");
            assert_eq!(e.kind, JobErrorKind::TimedOut, "job {i}: {e}");
            assert_eq!(e.index, i);
        } else {
            assert_eq!(*r.as_ref().unwrap(), i * 2, "order preserved at {i}");
            ok += 1;
        }
    }
    assert_eq!(
        completed.load(Ordering::Relaxed),
        ok,
        "every non-timed-out job runs exactly once (no duplicates, no losses)"
    );
}

/// Replicates `fault::maybe_hang`'s first-attempt decision draw (seed,
/// site `"sim"`, key = code, kind salt 4, attempt 0) from the crate's
/// public hash primitives, so the test can *choose* a seed with a known
/// hang pattern instead of hard-coding one and hoping.
fn hang_draw(seed: u64, code: &str) -> f64 {
    let sk = mix64(fault::key_of("sim") ^ mix64(fault::key_of(code))) ^ mix64(4);
    let h = mix64(seed ^ sk ^ mix64(0x9E37_79B9_7F4A_7C15));
    (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Smallest seed for which exactly one of `codes` hangs at probability
/// `p` on its first attempt.
fn seed_hanging_exactly_one(codes: &[String], p: f64) -> u64 {
    (0u64..100_000)
        .find(|&s| codes.iter().filter(|c| hang_draw(s, c.as_str()) < p).count() == 1)
        .expect("some seed under 100k must hang exactly one function")
}

fn serialize(ps: &[FunctionProfile]) -> String {
    ps.iter()
        .map(|p| store::profile_to_json(p).to_string_compact())
        .collect::<Vec<_>>()
        .join("\n")
}

/// End-to-end: a sweep with an injected hang and `--job-timeout` loses
/// exactly the hung function — recorded as retryable in the checkpoint,
/// never half-written — and a fault-free `--resume` recomputes only it,
/// converging byte-identically to a clean sweep.
#[test]
fn hang_injected_sweep_times_out_and_resume_converges() {
    let dir = std::env::temp_dir().join(format!("damov-dl-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let specs: Vec<_> = registry::representatives().into_iter().take(4).collect();
    let codes: Vec<String> = specs.iter().map(|s| s.id.code()).collect();
    let opt = SweepOptions {
        scale: Scale(0.05),
        ..Default::default()
    };

    // --- 1. Clean baseline. -------------------------------------------
    let clean = Coordinator::new(&dir, 4).profiles("dl-clean", &specs, opt.clone(), true);
    assert_eq!(clean.len(), 4);

    // --- 2. Sweep under an injected hang + --job-timeout. -------------
    let hang_p = 0.1;
    let seed = seed_hanging_exactly_one(&codes, hang_p);
    let hung: Vec<String> = codes
        .iter()
        .filter(|c| hang_draw(seed, c.as_str()) < hang_p)
        .cloned()
        .collect();
    assert_eq!(hung.len(), 1);
    let hung = &hung[0];
    fault::reset_attempts();
    fault::set_override(Some(FaultSpec {
        hang_p,
        seed,
        ..Default::default()
    }));
    let partial = Coordinator::new(&dir, 4)
        .with_recovery(2, false)
        .with_deadlines(Some(Duration::from_secs(2)), None)
        .profiles("dl", &specs, opt.clone(), true);
    fault::set_override(None);

    assert_eq!(
        partial.len(),
        3,
        "exactly the hung function (seed {seed}) must be missing"
    );
    assert!(
        !partial.iter().any(|p| &p.code == hung),
        "the hung function must not reach the result set"
    );

    // --- 3. The checkpoint: 3 intact profiles, 1 retryable, no torn
    //        record for the hung function. ------------------------------
    let fp = sweep_fingerprint(&specs, &opt);
    let ck = dir.join("checkpoint-dl.jsonl");
    assert!(ck.exists(), "partial sweep keeps its checkpoint for --resume");
    let ck_profiles = store::load_checkpoint(&ck, &fp);
    assert_eq!(ck_profiles.len(), 3, "no partial profile is ever checkpointed");
    assert!(!ck_profiles.iter().any(|p| &p.code == hung));
    let retryable = store::load_checkpoint_retryable(&ck, &fp);
    assert_eq!(retryable.len(), 1, "the timed-out function is recorded retryable");
    assert_eq!(&retryable[0].code, hung);
    assert_eq!(retryable[0].kind, "timed-out");
    assert_eq!(retryable[0].attempts, 1, "timeouts are not retried in-sweep");

    // --- 4. Fault-free --resume recomputes only the hung function and
    //        converges byte-identically. --------------------------------
    let calls_before = profile_call_count();
    let resumed = Coordinator::new(&dir, 4)
        .with_recovery(0, true)
        .profiles("dl", &specs, opt, false);
    assert_eq!(
        profile_call_count() - calls_before,
        1,
        "--resume must recompute exactly the timed-out function"
    );
    assert_eq!(resumed.len(), 4);
    assert_eq!(
        serialize(&clean),
        serialize(&resumed),
        "timeout-recovering resume must equal the clean sweep byte-for-byte"
    );
    assert!(!ck.exists(), "completed sweep retires its checkpoint");
    assert!(store::load_profiles_keyed(&dir.join("profiles-dl.json"), &fp).is_some());

    std::fs::remove_dir_all(&dir).ok();
}
