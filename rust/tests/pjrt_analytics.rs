//! Integration: the AOT-compiled JAX/Pallas artifacts, executed through
//! the PJRT CPU client, must agree with the pure-Rust oracles on real
//! workload traces. This closes the three-layer loop:
//! Pallas kernel == jnp ref (pytest) == Rust oracle (here) == artifact.
//!
//! Requires `make artifacts` (skips with a notice otherwise) and a build
//! with `--features pjrt`; the default offline build ships the stub
//! runtime whose `load` always degrades to the native Rust path, so the
//! whole file is compiled out.
#![cfg(feature = "pjrt")]

use damov::methodology::{cluster, locality};
use damov::runtime::{artifact, Analytics};
use damov::util::rng::Xoshiro256;
use damov::workloads::{registry, Scale};

fn load_or_skip() -> Option<Analytics> {
    if !artifact::artifacts_available() {
        eprintln!("[skip] artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Analytics::load(&artifact::default_artifact_dir()).expect("loading artifacts"))
}

#[test]
fn locality_artifact_matches_rust_on_synthetic_streams() {
    let Some(an) = load_or_skip() else { return };
    let cases: Vec<Vec<u64>> = vec![
        (0..32 * 50).collect(),                         // sequential
        (0..32 * 50).map(|i| i * 7).collect(),          // strided
        vec![42; 32 * 50],                              // single address
        {
            let mut rng = Xoshiro256::new(1);
            (0..32 * 200).map(|_| rng.gen_range(1 << 39)).collect()
        },
        {
            // RMW-ish triplets.
            let mut v = Vec::new();
            for i in 0..(32 * 40) {
                v.extend_from_slice(&[i, i, i]);
            }
            v
        },
    ];
    for (i, words) in cases.iter().enumerate() {
        let rust = locality::locality_of_words(words);
        let pjrt = an.locality_of_words(words).expect("artifact run");
        assert!(
            (rust.spatial - pjrt.spatial).abs() < 1e-9,
            "case {i}: spatial rust={} pjrt={}",
            rust.spatial,
            pjrt.spatial
        );
        assert!(
            (rust.temporal - pjrt.temporal).abs() < 1e-9,
            "case {i}: temporal rust={} pjrt={}",
            rust.temporal,
            pjrt.temporal
        );
        assert_eq!(rust.windows, pjrt.windows);
    }
}

#[test]
fn locality_artifact_matches_rust_on_workload_traces() {
    let Some(an) = load_or_skip() else { return };
    for code in ["STRTriad", "PLYGramSch", "CHAHsti", "LIGPrkEmd", "PLY3mm"] {
        let spec = registry::by_code(code).unwrap();
        let trace = spec.locality_trace(Scale::tiny());
        let rust = locality::locality(&trace);
        let pjrt = an.locality(&trace).expect("artifact run");
        assert!(
            (rust.spatial - pjrt.spatial).abs() < 1e-9,
            "{code}: spatial rust={} pjrt={}",
            rust.spatial,
            pjrt.spatial
        );
        assert!(
            (rust.temporal - pjrt.temporal).abs() < 1e-9,
            "{code}: temporal rust={} pjrt={}",
            rust.temporal,
            pjrt.temporal
        );
    }
}

#[test]
fn locality_artifact_handles_multi_chunk_traces() {
    let Some(an) = load_or_skip() else { return };
    // > CHUNK_WINDOWS (4096) windows => exercises the streaming path.
    let mut rng = Xoshiro256::new(5);
    let words: Vec<u64> = (0..32 * 5000).map(|_| rng.gen_range(1 << 30)).collect();
    let rust = locality::locality_of_words(&words);
    let pjrt = an.locality_of_words(&words).expect("artifact run");
    assert_eq!(rust.windows, 5000);
    assert!((rust.spatial - pjrt.spatial).abs() < 1e-9);
    assert!((rust.temporal - pjrt.temporal).abs() < 1e-9);
}

#[test]
fn kmeans_artifact_matches_rust() {
    let Some(an) = load_or_skip() else { return };
    // Two well-separated blobs in 5-D (the classification feature space).
    let mut rng = Xoshiro256::new(11);
    let mut points = Vec::new();
    for _ in 0..22 {
        points.push((0..5).map(|_| rng.gen_f64() * 0.1).collect::<Vec<f64>>());
    }
    for _ in 0..22 {
        points.push((0..5).map(|_| 0.9 + rng.gen_f64() * 0.1).collect::<Vec<f64>>());
    }
    let (rust_assign, _) = cluster::kmeans(&points, 2, 30, 7);
    let (pjrt_assign, pjrt_centroids) = an.kmeans(&points, 2, 30, 7).expect("kmeans artifact");
    // Same partition (labels may swap).
    let same = rust_assign == pjrt_assign
        || rust_assign
            .iter()
            .zip(&pjrt_assign)
            .all(|(&a, &b)| a == 1 - b);
    assert!(same, "rust={rust_assign:?} pjrt={pjrt_assign:?}");
    assert_eq!(pjrt_centroids.len(), 2);
    assert_eq!(pjrt_centroids[0].len(), 5);
}

#[test]
fn kmeans_single_step_matches_rust_assignment() {
    let Some(an) = load_or_skip() else { return };
    let mut rng = Xoshiro256::new(3);
    let points: Vec<Vec<f64>> = (0..44)
        .map(|_| (0..5).map(|_| rng.gen_f64()).collect())
        .collect();
    let centroids: Vec<Vec<f64>> = (0..6)
        .map(|_| (0..5).map(|_| rng.gen_f64()).collect())
        .collect();
    let rust_assign = cluster::kmeans_assign(&points, &centroids);
    let (pjrt_assign, _) = an.kmeans_step(&points, &centroids).expect("step");
    assert_eq!(rust_assign, pjrt_assign);
}
