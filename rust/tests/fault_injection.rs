//! End-to-end fault-tolerance proof (ISSUE acceptance criterion): a
//! characterization sweep running under ~10% injected panics and I/O
//! errors must converge — with bounded retries — to results
//! byte-identical to a clean sweep, and a sweep killed mid-run must be
//! resumable, recomputing only the unfinished functions.
//!
//! This file deliberately contains a SINGLE `#[test]`: the fault
//! override, the per-site attempt counters, and the profile-call
//! counter are process-global, so sharing the process with other tests
//! would race. Everything sequential lives here, in order.

use damov::coordinator::{store, sweep_fingerprint, Coordinator};
use damov::methodology::step3::{
    profile_call_count, profile_function_tuned, FunctionProfile, ReplayParallelism, SweepOptions,
};
use damov::util::fault::{self, FaultSpec};
use damov::workloads::{registry, Scale};

/// Canonical byte-level serialization of a result set, for
/// byte-identical comparison across runs.
fn serialize(ps: &[FunctionProfile]) -> String {
    ps.iter()
        .map(|p| store::profile_to_json(p).to_string_compact())
        .collect::<Vec<_>>()
        .join("\n")
}

#[test]
fn faulty_sweep_converges_and_resume_recomputes_only_unfinished() {
    let dir = std::env::temp_dir().join(format!("damov-fi-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let specs: Vec<_> = registry::representatives().into_iter().take(4).collect();
    let opt = SweepOptions {
        scale: Scale(0.05),
        ..Default::default()
    };

    // Injected panics are expected and caught; keep them out of the test
    // output. Real panics (e.g. assertion failures) still reach the
    // previous hook.
    let prev_hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(move |info| {
        let msg = info
            .payload()
            .downcast_ref::<String>()
            .map(String::as_str)
            .or_else(|| info.payload().downcast_ref::<&str>().copied())
            .unwrap_or("");
        if !msg.contains(fault::FAULT_MARKER) {
            prev_hook(info);
        }
    }));

    // --- 1. The env-var activation path parses and deactivates. -------
    std::env::set_var("DAMOV_FAULT_SPEC", "panic:0.5,io:0.25,seed:42");
    let s = fault::current().expect("DAMOV_FAULT_SPEC must activate injection");
    assert!((s.panic_p - 0.5).abs() < 1e-12);
    assert!((s.io_p - 0.25).abs() < 1e-12);
    assert_eq!(s.seed, 42);
    std::env::remove_var("DAMOV_FAULT_SPEC");
    assert!(fault::current().is_none(), "no spec, no faults");

    // --- 2. Injection verifiably fires under an override. -------------
    fault::reset_attempts();
    fault::set_override(Some(FaultSpec {
        io_p: 0.5,
        seed: 1234,
        ..Default::default()
    }));
    let before = fault::injected_count();
    let fired = (0..200u64)
        .filter(|&k| fault::maybe_io("probe", k).is_err())
        .count();
    assert!((50..150).contains(&fired), "io faults at p=0.5: fired={fired}");
    assert_eq!(fault::injected_count() - before, fired as u64);

    // --- 3. Clean baseline sweep. --------------------------------------
    fault::set_override(None);
    let clean = Coordinator::new(&dir, 4).profiles("clean", &specs, opt.clone(), true);
    assert_eq!(clean.len(), 4);

    // --- 4. Sweep under ~10% faults converges byte-identically. --------
    fault::reset_attempts();
    fault::set_override(Some(FaultSpec {
        panic_p: 0.1,
        io_p: 0.1,
        delay_p: 0.2,
        seed: 1234,
        ..Default::default()
    }));
    let faulty = Coordinator::new(&dir, 4)
        .with_recovery(8, false)
        .profiles("fi", &specs, opt.clone(), true);
    fault::set_override(None);
    assert_eq!(
        faulty.len(),
        4,
        "8 retries at p=0.1 must push every function through"
    );
    assert_eq!(
        serialize(&clean),
        serialize(&faulty),
        "fault-injected sweep must converge to byte-identical profiles"
    );

    // --- 5. A killed sweep resumes, recomputing only the rest. ---------
    // Emulate a sweep killed after 2 of 4 functions: a checkpoint holding
    // the first two records and no cache file for its tag.
    let fp = sweep_fingerprint(&specs, &opt);
    let ck = dir.join("checkpoint-res.jsonl");
    let w = store::CheckpointWriter::create(&ck, &fp, false).unwrap();
    w.append(&clean[0]).unwrap();
    w.append(&clean[1]).unwrap();
    drop(w);

    let calls_before = profile_call_count();
    let resumed = Coordinator::new(&dir, 2)
        .with_recovery(0, true)
        .profiles("res", &specs, opt.clone(), false);
    assert_eq!(
        profile_call_count() - calls_before,
        2,
        "resume must recompute only the 2 unfinished functions"
    );
    assert_eq!(resumed.len(), 4);
    assert_eq!(
        serialize(&clean),
        serialize(&resumed),
        "resumed sweep must equal the clean sweep"
    );
    // Completed: cache written and keyed, checkpoint retired.
    assert!(!ck.exists());
    assert!(store::load_profiles_keyed(&dir.join("profiles-res.json"), &fp).is_some());

    // --- 6. Parallel config replay under faults == serial clean run,
    //        and the call counter counts exactly the completions. -------
    // Serial reference: the historical one-config-at-a-time replay loop,
    // no faults, no worker pool.
    let serial_ref: Vec<FunctionProfile> = specs
        .iter()
        .map(|s| profile_function_tuned(s, opt.clone(), ReplayParallelism::Serial))
        .collect();
    assert_eq!(
        serialize(&clean),
        serialize(&serial_ref),
        "parallel coordinator sweep must equal the serial replay reference"
    );
    // Faulty parallel run: outer workers AND inner config-point lanes
    // race while ~10% of jobs panic at the sim boundary and I/O faults
    // hit the store; retries must converge to the same bytes.
    fault::reset_attempts();
    fault::set_override(Some(FaultSpec {
        panic_p: 0.1,
        io_p: 0.1,
        seed: 77,
        ..Default::default()
    }));
    let calls_before = profile_call_count();
    let par_faulty = Coordinator::new(&dir, 2)
        .with_recovery(8, false)
        .profiles("fi-par", &specs, opt, true);
    fault::set_override(None);
    assert_eq!(par_faulty.len(), 4);
    assert_eq!(
        profile_call_count() - calls_before,
        4,
        "profile_call_count increments once per COMPLETED profile: \
         panicked/retried attempts never count (completion-ordered contract)"
    );
    assert_eq!(
        serialize(&serial_ref),
        serialize(&par_faulty),
        "faulty parallel-replay sweep must converge to the serial reference bytes"
    );

    std::fs::remove_dir_all(&dir).ok();
}
