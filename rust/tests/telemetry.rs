//! Integration tests for the telemetry subsystem (metrics registry,
//! Chrome-trace spans, structured event log) — the ISSUE acceptance
//! criteria: concurrent counters stay exact under `par_map_catch`, the
//! exported trace is valid Chrome trace-event JSON (parseable, monotonic
//! timestamps, matched B/E pairs per lane), simulation results are
//! bit-identical with tracing on vs off, fault-injection decisions are
//! logged as structured events, and metrics snapshots survive the
//! checkpoint round-trip that `--resume` relies on.
//!
//! Trace/log state is process-global, so every test serializes on
//! [`TELEMETRY_LOCK`] (poison-recovering: an assertion failure in one
//! test must not abort the rest).

use damov::coordinator::store::{self, CheckpointWriter};
use damov::methodology::step3::{profile_function, SweepOptions};
use damov::util::fault::{self, FaultSpec};
use damov::util::json::Json;
use damov::util::pool::par_map_catch;
use damov::util::telemetry::{log, metrics, trace, Level};
use damov::workloads::{registry, Scale};
use std::collections::HashMap;
use std::sync::Mutex;

static TELEMETRY_LOCK: Mutex<()> = Mutex::new(());

fn gate() -> std::sync::MutexGuard<'static, ()> {
    TELEMETRY_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("damov-telemetry-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).expect("create temp dir");
    d
}

/// Validate a Chrome trace document: every event well-formed, timestamps
/// globally monotonic (non-decreasing), and per-lane `B`/`E` events
/// properly nested with empty stacks at the end. Returns (B, E) counts.
fn validate_chrome_trace(doc: &Json) -> (usize, usize) {
    let evs = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array");
    let mut last_ts = 0.0;
    let mut stacks: HashMap<u64, usize> = HashMap::new();
    let mut n_b = 0;
    let mut n_e = 0;
    for e in evs {
        let ph = e.get("ph").and_then(Json::as_str).expect("ph");
        let ts = e.get("ts").and_then(Json::as_f64).expect("ts");
        let tid = e.get("tid").and_then(Json::as_f64).expect("tid") as u64;
        assert_eq!(e.get("pid").and_then(Json::as_f64), Some(1.0));
        assert!(ts >= last_ts, "ts went backwards: {ts} < {last_ts}");
        last_ts = ts;
        match ph {
            "B" => {
                assert!(e.get("name").is_some(), "B event without a name");
                *stacks.entry(tid).or_insert(0) += 1;
                n_b += 1;
            }
            "E" => {
                let depth = stacks.entry(tid).or_insert(0);
                assert!(*depth > 0, "E without a matching B on lane {tid}");
                *depth -= 1;
                n_e += 1;
            }
            "M" => {
                assert_eq!(
                    e.get("name").and_then(Json::as_str),
                    Some("thread_name"),
                    "metadata events label lanes"
                );
            }
            "i" => {
                // Instant events (cancellations, deadline hits) carry a
                // name and thread scope but no duration to nest.
                assert!(e.get("name").is_some(), "i event without a name");
                assert_eq!(
                    e.get("s").and_then(Json::as_str),
                    Some("t"),
                    "instant events use thread scope"
                );
            }
            other => panic!("unexpected phase {other:?}"),
        }
    }
    for (tid, depth) in &stacks {
        assert_eq!(*depth, 0, "lane {tid} ended with {depth} unclosed span(s)");
    }
    (n_b, n_e)
}

fn tiny_opt() -> SweepOptions {
    SweepOptions {
        scale: Scale(0.05),
        ..Default::default()
    }
}

#[test]
fn metrics_stay_exact_under_parallel_load() {
    let _g = gate();
    let c = metrics::counter("itest.par.counter");
    let h = metrics::histogram("itest.par.hist");
    let (c0, h_count0, h_sum0) = (c.get(), h.count(), h.sum());

    let items: Vec<u64> = (0..512).collect();
    let out = par_map_catch(&items, 8, 0, |&x| {
        metrics::counter("itest.par.counter").incr();
        metrics::histogram("itest.par.hist").record(x);
        x
    });
    assert_eq!(out.len(), 512);
    assert!(out.iter().all(|r| r.is_ok()));

    assert_eq!(c.get() - c0, 512, "counter lost increments under contention");
    assert_eq!(h.count() - h_count0, 512);
    // sum of 0..512 = 511*512/2
    assert_eq!(h.sum() - h_sum0, 511 * 512 / 2);
    assert_eq!(h.min(), 0);
    assert!(h.max() >= 511);
}

#[test]
fn chrome_trace_export_is_valid_json() {
    let _g = gate();
    let _ = trace::take_events_json(); // start from an empty buffer
    trace::enable(None);

    let items: Vec<u64> = (0..64).collect();
    let out = par_map_catch(&items, 4, 0, |&x| {
        let _s = trace::span_args("unit-work", vec![("x".to_string(), Json::from(x))]);
        x * 2
    });
    assert!(out.iter().all(|r| r.is_ok()));

    trace::disable();
    let doc = trace::take_events_json();

    // The document must survive a serialize → parse round-trip.
    let text = doc.to_string_compact();
    let reparsed = Json::parse(&text).expect("exported trace must be valid JSON");
    assert_eq!(
        reparsed.get("displayTimeUnit").and_then(Json::as_str),
        Some("ms")
    );

    let (n_b, n_e) = validate_chrome_trace(&reparsed);
    assert_eq!(n_b, n_e, "every span must close");
    // 64 pool "job" spans + 64 explicit "unit-work" spans.
    assert!(n_b >= 128, "expected >=128 spans, got {n_b}");
}

#[test]
fn trace_spans_close_even_when_jobs_panic() {
    let _g = gate();
    let _ = trace::take_events_json();
    trace::enable(None);

    let items: Vec<u32> = (0..8).collect();
    let out = par_map_catch(&items, 2, 1, |&x| {
        if x == 3 {
            panic!("telemetry-test: intended panic");
        }
        x
    });
    assert!(out[3].is_err());

    trace::disable();
    let doc = trace::take_events_json();
    let (n_b, n_e) = validate_chrome_trace(&doc);
    assert_eq!(n_b, n_e, "panicking jobs must still close their spans");
    // 7 clean jobs + 2 attempts of the cursed one.
    assert_eq!(n_b, 9);
}

#[test]
fn simulation_is_bit_identical_with_tracing_on() {
    let _g = gate();
    let spec = registry::by_code("STRCpy").expect("suite function");

    trace::disable();
    let off = store::profile_to_json(&profile_function(&spec, tiny_opt())).to_string_compact();

    let _ = trace::take_events_json();
    trace::enable(None);
    let on = store::profile_to_json(&profile_function(&spec, tiny_opt())).to_string_compact();
    trace::disable();
    let doc = trace::take_events_json();

    assert_eq!(off, on, "tracing must not perturb simulation results");
    // The traced run actually recorded spans (profile + trace-gen + ...).
    let evs = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
    assert!(!evs.is_empty(), "traced run produced no events");
}

#[test]
fn fault_decisions_are_logged_as_structured_events() {
    let _g = gate();
    let dir = tmp_dir("faultlog");
    let path = dir.join("events.jsonl");
    let _ = std::fs::remove_file(&path);

    log::set_file(Some(&path)).expect("open log file");
    log::set_level(Level::Debug);
    fault::reset_attempts();
    fault::set_override(Some(FaultSpec {
        io_p: 1.0,
        seed: 7,
        ..Default::default()
    }));

    let hit = fault::maybe_io("itest-site", 42);

    // Restore global state before asserting, so a failure here cannot
    // leak a fault spec or log redirection into later tests.
    fault::set_override(None);
    log::set_file(None).unwrap();
    log::set_level(Level::Info);

    assert!(hit.is_err(), "io_p=1.0 must inject");
    let text = std::fs::read_to_string(&path).expect("log file written");
    let fault_events: Vec<Json> = text
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| Json::parse(l).expect("every log line is valid JSON"))
        .filter(|j| j.get("kind").and_then(Json::as_str) == Some("fault"))
        .collect();
    assert!(!fault_events.is_empty(), "no fault events logged");
    let ev = &fault_events[0];
    assert_eq!(ev.get("level").and_then(Json::as_str), Some("info"));
    let f = ev.get("fields").expect("fields object");
    assert_eq!(f.get("kind").and_then(Json::as_str), Some("io"));
    assert_eq!(f.get("site").and_then(Json::as_str), Some("itest-site"));
    assert_eq!(f.get("verdict").and_then(Json::as_str), Some("inject"));
    assert!(f.get("attempt").and_then(Json::as_f64).is_some());

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn checkpoint_metrics_roundtrip_and_absorb() {
    let _g = gate();
    let dir = tmp_dir("ckpt");
    let path = dir.join("ckpt.jsonl");
    let fp = "telemetry-itest-fp";

    let p1 = profile_function(&registry::by_code("STRCpy").unwrap(), tiny_opt());
    let p2 = profile_function(&registry::by_code("STRTriad").unwrap(), tiny_opt());

    // Hand-built snapshot naming only this test's metric, so absorbing
    // it cannot interfere with concurrently updated global metrics.
    let mut counters = Json::obj();
    counters.set("itest.ckpt.counter", 5u64);
    let mut snap = Json::obj();
    snap.set("counters", counters)
        .set("gauges", Json::obj())
        .set("histograms", Json::obj());

    {
        let w = CheckpointWriter::create(&path, fp, false).unwrap();
        w.append(&p1).unwrap();
        w.append_metrics(&snap).unwrap();
        w.append(&p2).unwrap();
    }

    // Profile records load; the interleaved metrics line is skipped.
    let recs = store::load_checkpoint(&path, fp);
    assert_eq!(recs.len(), 2);
    assert_eq!(recs[0].code, "STRCpy");
    assert_eq!(recs[1].code, "STRTriad");

    // The snapshot survives the round-trip checksum-intact …
    let loaded = store::load_checkpoint_metrics(&path, fp).expect("metrics line");
    assert_eq!(
        loaded
            .get("counters")
            .and_then(|c| c.get("itest.ckpt.counter"))
            .and_then(Json::as_f64),
        Some(5.0)
    );
    // … and absorbing it adds to the live registry (the --resume path).
    let c = metrics::counter("itest.ckpt.counter");
    let before = c.get();
    metrics::absorb(&loaded);
    assert_eq!(c.get(), before + 5);

    // A corrupted metrics line is rejected, not served.
    let mut text = std::fs::read_to_string(&path).unwrap();
    text = text.replace("\"itest.ckpt.counter\":5", "\"itest.ckpt.counter\":9");
    let tampered = dir.join("tampered.jsonl");
    std::fs::write(&tampered, text).unwrap();
    assert!(store::load_checkpoint_metrics(&tampered, fp).is_none());

    let _ = std::fs::remove_dir_all(&dir);
}

/// CI smoke validation: after running the `damov` binary with
/// `DAMOV_TRACE`/`DAMOV_LOG` set, this test (run with `--ignored`)
/// checks that the artifacts it produced are well-formed. The paths
/// arrive via `DAMOV_SMOKE_TRACE` / `DAMOV_SMOKE_LOG`.
#[test]
#[ignore]
fn smoke_validate_artifacts() {
    let trace_path = std::env::var("DAMOV_SMOKE_TRACE").expect("DAMOV_SMOKE_TRACE not set");
    let log_path = std::env::var("DAMOV_SMOKE_LOG").expect("DAMOV_SMOKE_LOG not set");

    let text = std::fs::read_to_string(&trace_path).expect("trace file exists");
    let doc = Json::parse(&text).expect("trace file is valid JSON");
    let (n_b, n_e) = validate_chrome_trace(&doc);
    assert!(n_b > 0, "binary run recorded no spans");
    assert_eq!(n_b, n_e, "unmatched spans in exported trace");

    let text = std::fs::read_to_string(&log_path).expect("log file exists");
    let mut events = 0;
    for line in text.lines().filter(|l| !l.trim().is_empty()) {
        let j = Json::parse(line).expect("every log line is valid JSON");
        assert!(j.get("ts_us").is_some());
        assert!(j.get("level").and_then(Json::as_str).is_some());
        assert!(j.get("kind").and_then(Json::as_str).is_some());
        events += 1;
    }
    assert!(events > 0, "binary run logged no events");
}
