//! Property-based invariants of the simulator and coordinator substrate:
//! randomized workloads/configurations must never violate the physical
//! and accounting laws the methodology depends on.

use damov::sim::{simulate, Access, CoreModel, SystemConfig};
use damov::util::prop;
use damov::util::rng::Xoshiro256;

/// Random but well-formed multi-core trace.
fn random_trace(rng: &mut Xoshiro256, cores: usize) -> Vec<Vec<Access>> {
    (0..cores)
        .map(|c| {
            let n = rng.gen_usize(50, 3000);
            let base = 0x1000_0000u64 + c as u64 * (1 << 28);
            let ws = 1u64 << rng.gen_usize(8, 22); // working set in words
            (0..n)
                .map(|_| {
                    let addr = base + rng.gen_range(ws) * 8;
                    let gap = rng.gen_range(30) as u16;
                    let ops = rng.gen_range(8) as u16;
                    match rng.gen_usize(0, 4) {
                        0 => Access::store(addr, gap, ops),
                        1 => Access::load_dep(addr, gap, ops),
                        _ => Access::load(addr, gap, ops),
                    }
                })
                .collect()
        })
        .collect()
}

fn random_config(rng: &mut Xoshiro256, cores: usize) -> SystemConfig {
    let model = if rng.gen_bool(0.5) {
        CoreModel::OutOfOrder
    } else {
        CoreModel::InOrder
    };
    match rng.gen_usize(0, 4) {
        0 => SystemConfig::host(cores, model),
        1 => SystemConfig::host_prefetch(cores, model),
        2 => SystemConfig::ndp(cores, model),
        _ => SystemConfig::host_nuca(cores, model),
    }
}

#[test]
fn accounting_laws_hold_for_random_workloads() {
    prop::check(40, |rng| {
        let cores = [1, 2, 4, 8][rng.gen_usize(0, 4)];
        let trace = random_trace(rng, cores);
        let cfg = random_config(rng, cores);
        let r = simulate(&cfg, &trace);

        // Time and cycles strictly positive and consistent.
        assert!(r.time_s > 0.0 && r.cycles > 0.0);
        assert!((r.time_s - r.cycles / cfg.freq_hz).abs() / r.time_s < 1e-9);
        // IPC bounded by issue width x cores.
        assert!(r.ipc > 0.0 && r.ipc <= (cfg.issue_width as f64) * cores as f64 + 1e-9);
        // Ratios in range.
        assert!((0.0..=1.0).contains(&r.memory_bound));
        assert!((0.0..=1.0 + 1e-9).contains(&r.lfmr), "lfmr={}", r.lfmr);
        assert!((0.0..=1.0).contains(&r.row_hit_rate));
        assert!(r.pf_accuracy >= 0.0 && r.pf_accuracy <= 1.0);
        // Level fractions are a distribution over the loads.
        let s: f64 = r.level_fracs.iter().sum();
        let loads = trace
            .iter()
            .flatten()
            .filter(|a| !a.write)
            .count();
        if loads > 0 {
            assert!((s - 1.0).abs() < 1e-6, "level fracs sum {s}");
        }
        // Cache conservation: hits + misses == demand accesses at L1.
        let accesses: u64 = trace.iter().map(|t| t.len() as u64).sum();
        let ndp_stores = if cfg.l1_read_only {
            trace.iter().flatten().filter(|a| a.write).count() as u64
        } else {
            0
        };
        assert_eq!(r.l1_hits + r.l1_misses + ndp_stores, accesses);
        // Energy components non-negative; NDP never pays L2/L3/link.
        let e = r.energy;
        for v in [e.l1, e.l2, e.l3, e.dram, e.link, e.noc] {
            assert!(v >= 0.0);
        }
        if cfg.is_direct_vault() {
            assert_eq!(e.l2 + e.l3 + e.link, 0.0);
        }
        // Bandwidth never exceeds the configured peak.
        assert!(
            r.bw_bytes_s <= cfg.peak_bw() * 1.0001,
            "bw {} > peak {}",
            r.bw_bytes_s,
            cfg.peak_bw()
        );
        // Basic-block miss attribution never exceeds total L3 misses+1
        // slack for NDP DRAM accounting.
        let bb_total: u64 = r.bb_llc_misses.iter().sum();
        if cfg.l3.is_some() {
            assert!(bb_total <= r.l3_misses + r.l1_misses);
        }
    });
}

#[test]
fn determinism_across_repeated_runs() {
    prop::check(10, |rng| {
        let cores = [1, 4][rng.gen_usize(0, 2)];
        let trace = random_trace(rng, cores);
        let cfg = random_config(rng, cores);
        let a = simulate(&cfg, &trace);
        let b = simulate(&cfg, &trace);
        assert_eq!(a.cycles.to_bits(), b.cycles.to_bits());
        assert_eq!(a.l3_misses, b.l3_misses);
        assert_eq!(a.energy, b.energy);
        assert_eq!(a.bb_llc_misses, b.bb_llc_misses);
    });
}

#[test]
fn more_cache_never_hurts_hit_count() {
    // Host (3 levels) must never see *more* DRAM demand traffic than NDP
    // (1 level) on the identical trace — the hierarchy can only filter.
    prop::check(25, |rng| {
        let cores = [1, 2, 4][rng.gen_usize(0, 3)];
        let trace = random_trace(rng, cores);
        let host = simulate(&SystemConfig::host(cores, CoreModel::OutOfOrder), &trace);
        let ndp = simulate(&SystemConfig::ndp(cores, CoreModel::OutOfOrder), &trace);
        let host_demand_reads = host.dram_reads;
        let ndp_demand_reads = ndp.dram_reads;
        assert!(
            host_demand_reads <= ndp_demand_reads + ndp_demand_reads / 10 + 16,
            "host dram reads {host_demand_reads} > ndp {ndp_demand_reads}"
        );
    });
}

#[test]
fn memory_bound_increases_with_dependence() {
    // Making every load dependent can only increase memory-boundedness.
    prop::check(15, |rng| {
        let cores = 2;
        let indep = random_trace(rng, cores);
        let dep: Vec<Vec<Access>> = indep
            .iter()
            .map(|t| {
                t.iter()
                    .map(|a| {
                        let mut a = *a;
                        if !a.write {
                            a.dep = true;
                        }
                        a
                    })
                    .collect()
            })
            .collect();
        let cfg = SystemConfig::host(cores, CoreModel::OutOfOrder);
        let r_i = simulate(&cfg, &indep);
        let r_d = simulate(&cfg, &dep);
        assert!(
            r_d.memory_bound >= r_i.memory_bound - 1e-9,
            "dep {} < indep {}",
            r_d.memory_bound,
            r_i.memory_bound
        );
        assert!(r_d.time_s >= r_i.time_s * 0.999);
    });
}

#[test]
fn workload_traces_strong_scale_exactly() {
    // Every registry function must emit the same total work for any
    // thread count (the scalability sweep depends on it).
    use damov::workloads::{registry, Scale};
    prop::check(12, |rng| {
        let fns = registry::representatives();
        let spec = &fns[rng.gen_usize(0, fns.len())];
        let t1: usize = spec.trace(1, Scale::tiny()).iter().map(Vec::len).sum();
        let cores = [2, 3, 8, 64][rng.gen_usize(0, 4)];
        let tn: usize = spec.trace(cores, Scale::tiny()).iter().map(Vec::len).sum();
        let tol = t1 / 5 + 2048; // block-granular partitioning slack
        assert!(
            t1.abs_diff(tn) <= tol,
            "{}: {} vs {} accesses at {} cores",
            spec.id.code(),
            t1,
            tn,
            cores
        );
    });
}
