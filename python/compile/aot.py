"""AOT lowering: jax/Pallas (Layers 1-2) -> HLO *text* artifacts for the
Rust PJRT runtime (Layer 3).

HLO text — not ``.serialize()`` — is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` crate binds) rejects; the text
parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md and gen_hlo.py.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (from python/).
Produces:
  artifacts/locality.hlo.txt   — locality_chunk
  artifacts/kmeans.hlo.txt     — kmeans_iteration
  artifacts/manifest.json      — shapes/dtypes for the Rust loader
"""

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="legacy single-file alias (ignored)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    artifacts = {}

    lowered = jax.jit(model.locality_chunk).lower(*model.locality_example_args())
    path = os.path.join(args.out_dir, "locality.hlo.txt")
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    artifacts["locality"] = {
        "file": "locality.hlo.txt",
        "chunk_windows": model.CHUNK_WINDOWS,
        "window": model.WINDOW,
        "inputs": ["f64[CHUNK,32] windows", "f64[CHUNK] mask"],
        "outputs": ["f64 spatial_sum", "f64 temporal_sum", "f64 n_valid"],
    }
    print(f"wrote {path} ({len(text)} chars)")

    lowered = jax.jit(model.kmeans_iteration).lower(*model.kmeans_example_args())
    path = os.path.join(args.out_dir, "kmeans.hlo.txt")
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    artifacts["kmeans"] = {
        "file": "kmeans.hlo.txt",
        "points": model.KM_POINTS,
        "centroids": model.KM_CENTROIDS,
        "features": model.KM_FEATURES,
        "inputs": ["f32[N,F] points", "f32[K,F] centroids", "f32[N] mask"],
        "outputs": ["i32[N] assign", "f32[K,F] centroids"],
    }
    print(f"wrote {path} ({len(text)} chars)")

    manifest = os.path.join(args.out_dir, "manifest.json")
    with open(manifest, "w") as f:
        json.dump(artifacts, f, indent=2)
    print(f"wrote {manifest}")


if __name__ == "__main__":
    main()
