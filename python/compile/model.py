"""Layer-2 JAX models: the Step-2 analytics pipelines, composed from the
Layer-1 Pallas kernels and lowered once by aot.py.

Two entry points, each a fixed-shape jitted function:

* ``locality_chunk`` — one trace chunk of CHUNK_WINDOWS x 32 word
  addresses + validity mask -> (spatial_sum, temporal_sum, n_valid).
  The Rust runtime streams a function's trace through this artifact in
  chunks and combines the partial sums.
* ``kmeans_iteration`` — padded (64, 8) feature matrix + (8, 8)
  centroids + mask -> (assignments, new centroids). Rust iterates to a
  fixed point.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from .kernels import kmeans as kmeans_kernel
from .kernels import locality as locality_kernel

# Fixed artifact geometry (must match rust/src/runtime/analytics.rs).
CHUNK_WINDOWS = 4096
WINDOW = locality_kernel.WINDOW  # 32
KM_POINTS = kmeans_kernel.N_POINTS  # 64
KM_CENTROIDS = kmeans_kernel.N_CENTROIDS  # 8
KM_FEATURES = kmeans_kernel.N_FEATURES  # 8


def locality_chunk(windows, mask):
    """(CHUNK_WINDOWS, 32) f64 addresses + (CHUNK_WINDOWS,) f64 mask ->
    (spatial_sum, temporal_sum, n_valid), all f64 scalars."""
    spatial, temporal = locality_kernel.locality_windows(windows, mask)
    return spatial, temporal, mask.sum()


def kmeans_iteration(points, centroids, mask):
    """One Lloyd iteration over the padded feature matrix.

    Returns (assignments (N,) i32, new_centroids (K, F) f32).
    """
    assign, new = kmeans_kernel.kmeans_step(points, centroids, mask)
    return assign, new


def locality_example_args():
    spec = jax.ShapeDtypeStruct((CHUNK_WINDOWS, WINDOW), jnp.float64)
    mask = jax.ShapeDtypeStruct((CHUNK_WINDOWS,), jnp.float64)
    return (spec, mask)


def kmeans_example_args():
    pts = jax.ShapeDtypeStruct((KM_POINTS, KM_FEATURES), jnp.float32)
    cent = jax.ShapeDtypeStruct((KM_CENTROIDS, KM_FEATURES), jnp.float32)
    mask = jax.ShapeDtypeStruct((KM_POINTS,), jnp.float32)
    return (pts, cent, mask)
