"""Layer-1 Pallas kernel: windowed spatial/temporal locality (Eqs. 1-2).

The trace analytics hot-spot of Step 2: for every non-overlapping window
of W=32 word addresses, compute

* the spatial contribution ``1 / min_nonzero_pairwise_distance`` and
* the temporal contribution ``sum_i [k_i>=2] * 2^floor(log2 k_i) / k_i``
  (``k_i`` = occurrences of the address at position i in the window),

then reduce over the window tile. The O(W^2) pairwise compare is
expressed as a broadcast (TILE, 32, 32) abs-diff/equality block — pure
VPU work with no gather/scatter (see DESIGN.md §Hardware-Adaptation).

BlockSpec moves TILE=256 windows (256 x 32 x 8 B = 64 KiB) HBM->VMEM per
grid step, comfortably inside VMEM even with the (256,32,32) f32
intermediate (8 MiB is the budget; the intermediate is built in two
halves of 4 MiB by the compiler's fusion, and at f64 input precision the
diff tensor is materialized once). ``interpret=True`` everywhere: the
CPU PJRT plugin cannot execute Mosaic custom-calls; real-TPU numbers are
estimated analytically in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)

WINDOW = 32


def pow2_floor(k):
    """Largest power of two <= k, exact for k in [1, 32].

    XLA's log2 lowering is not exact at powers of two (log2(8) can
    return 2.9999999999999996), so floor(log2(k)) silently drops a bin;
    a compare/select chain avoids the transcendental entirely.
    """
    return jnp.where(
        k >= 32.0,
        32.0,
        jnp.where(
            k >= 16.0,
            16.0,
            jnp.where(k >= 8.0, 8.0, jnp.where(k >= 4.0, 4.0, jnp.where(k >= 2.0, 2.0, 1.0))),
        ),
    )


TILE = 256  # windows per grid step


def _locality_kernel(win_ref, mask_ref, spat_ref, temp_ref):
    """Per-tile kernel: windows (TILE, 32) f64 -> per-window sums."""
    a = win_ref[...]  # (TILE, 32) f64
    m = mask_ref[...]  # (TILE,) f64
    d = jnp.abs(a[:, :, None] - a[:, None, :])  # (TILE, 32, 32)
    big = jnp.float64(2 ** 62)
    dm = jnp.where(d == 0.0, big, d)
    min_stride = dm.min(axis=(1, 2))
    spatial = jnp.where(min_stride >= big, 0.0, 1.0 / min_stride) * m
    eq = (d == 0.0).astype(jnp.float64)
    k = eq.sum(axis=2)  # (TILE, 32)
    contrib = jnp.where(k >= 2.0, pow2_floor(k) / k, 0.0)
    temporal = contrib.sum(axis=1) * m
    spat_ref[...] = spatial
    temp_ref[...] = temporal


@functools.partial(jax.jit, static_argnames=())
def locality_windows(windows: jnp.ndarray, mask: jnp.ndarray):
    """Pallas-tiled locality contributions.

    Args:
      windows: (N, 32) float64, N a multiple of TILE (callers pad).
      mask: (N,) float64 validity mask.

    Returns:
      (spatial_sum, temporal_sum) scalars (f64).
    """
    n = windows.shape[0]
    assert n % TILE == 0, f"window count {n} must be a multiple of {TILE}"
    grid = (n // TILE,)
    spatial, temporal = pl.pallas_call(
        _locality_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE, WINDOW), lambda i: (i, 0)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float64),
            jax.ShapeDtypeStruct((n,), jnp.float64),
        ],
        interpret=True,
    )(windows, mask)
    return spatial.sum(), temporal.sum()
