"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: `locality_windows_ref` mirrors the
Rust implementation in ``rust/src/methodology/locality.rs`` (the paper's
Eq. 1/2 at word granularity over 32-reference windows), and
`kmeans_assign_ref` mirrors ``methodology::cluster::kmeans_assign``.
pytest checks the Pallas kernels against these; the Rust runtime then
cross-checks the compiled artifacts against its own implementation,
closing the three-way loop.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

WINDOW = 32


def pow2_floor(k):
    """Largest power of two <= k, exact for k in [1, 32].

    XLA's log2 lowering is not exact at powers of two (log2(8) can
    return 2.9999999999999996), so floor(log2(k)) silently drops a bin;
    a compare/select chain avoids the transcendental entirely.
    """
    return jnp.where(
        k >= 32.0,
        32.0,
        jnp.where(
            k >= 16.0,
            16.0,
            jnp.where(k >= 8.0, 8.0, jnp.where(k >= 4.0, 4.0, jnp.where(k >= 2.0, 2.0, 1.0))),
        ),
    )




def locality_windows_ref(windows: jnp.ndarray, mask: jnp.ndarray):
    """Per-window locality contributions.

    Args:
      windows: (N, 32) float64 word addresses (integers stored exactly).
      mask: (N,) float64, 1.0 for valid windows, 0.0 for padding.

    Returns:
      (spatial_sum, temporal_sum): scalars, each the sum of the
      per-window contributions over valid windows. The caller divides by
      `n_windows` and `n_windows * 32` respectively.
    """
    a = windows.astype(jnp.float64)
    d = jnp.abs(a[:, :, None] - a[:, None, :])  # (N, 32, 32)
    big = jnp.float64(2**62)
    # Spatial: min non-zero pairwise distance -> 1/min (0 if none).
    dm = jnp.where(d == 0.0, big, d)
    min_stride = dm.min(axis=(1, 2))  # (N,)
    spatial = jnp.where(min_stride >= big, 0.0, 1.0 / min_stride)
    # Temporal: per position, occurrence count k of its address.
    eq = (d == 0.0).astype(jnp.float64)  # includes self: k_i = sum_j eq
    k = eq.sum(axis=2)  # (N, 32)
    contrib = jnp.where(k >= 2.0, pow2_floor(k) / k, 0.0)
    temporal = contrib.sum(axis=1)  # (N,)
    return (spatial * mask).sum(), (temporal * mask).sum()


def kmeans_assign_ref(points: jnp.ndarray, centroids: jnp.ndarray):
    """Nearest-centroid assignment.

    Args:
      points: (N, F) float.
      centroids: (K, F) float.

    Returns:
      (N,) int32 index of the nearest centroid (squared-L2).
    """
    d2 = ((points[:, None, :] - centroids[None, :, :]) ** 2).sum(axis=-1)
    return jnp.argmin(d2, axis=1).astype(jnp.int32)


def kmeans_update_ref(points, centroids, mask):
    """One full Lloyd iteration (assignment + masked centroid update)."""
    assign = kmeans_assign_ref(points, centroids)
    k = centroids.shape[0]
    onehot = (assign[:, None] == jnp.arange(k)[None, :]).astype(points.dtype)
    onehot = onehot * mask[:, None]
    counts = onehot.sum(axis=0)  # (K,)
    sums = onehot.T @ points  # (K, F)
    new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1.0), centroids)
    return assign, new
