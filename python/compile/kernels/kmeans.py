"""Layer-1 Pallas kernel: k-means assignment step.

Used by Step-2 clustering (Fig 3) and the §4.1 hierarchical-clustering
cross-check: assign each function's feature vector to its nearest
centroid (squared L2). The (N, K) distance matrix is built as a single
broadcast block — N=64 padded points x K=8 padded centroids x F=8
features is tiny (VMEM-trivial); the kernel exists to keep the entire
Step-2 analytics pipeline in one AOT artifact rather than for FLOPs.

``interpret=True``: see locality.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)

# Padded artifact shapes (see aot.py / runtime::analytics).
N_POINTS = 64
N_CENTROIDS = 8
N_FEATURES = 8


def _assign_kernel(pts_ref, cent_ref, out_ref):
    p = pts_ref[...]  # (N, F)
    c = cent_ref[...]  # (K, F)
    d2 = ((p[:, None, :] - c[None, :, :]) ** 2).sum(axis=-1)  # (N, K)
    out_ref[...] = jnp.argmin(d2, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=())
def kmeans_assign(points: jnp.ndarray, centroids: jnp.ndarray):
    """Nearest-centroid assignment via Pallas.

    Args:
      points: (N, F) float32.
      centroids: (K, F) float32.

    Returns:
      (N,) int32.
    """
    n, f = points.shape
    k = centroids.shape[0]
    return pl.pallas_call(
        _assign_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(points, centroids)


def kmeans_step(points, centroids, mask):
    """One Lloyd iteration: Pallas assignment + jnp masked update (L2)."""
    assign = kmeans_assign(points, centroids)
    k = centroids.shape[0]
    onehot = (assign[:, None] == jnp.arange(k)[None, :]).astype(points.dtype)
    onehot = onehot * mask[:, None]
    counts = onehot.sum(axis=0)
    sums = onehot.T @ points
    new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1.0), centroids)
    return assign, new
