"""Correctness tests: Pallas kernels vs pure-jnp oracles vs brute force.

The Pallas locality/k-means kernels are the Layer-1 hot path compiled
into the AOT artifacts; any divergence from the reference semantics
silently corrupts Step 2 of the methodology, so these tests are the core
correctness signal of the Python side.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from compile.kernels import kmeans as km
from compile.kernels import locality as loc
from compile.kernels import ref
from compile import model

TILE = loc.TILE
W = loc.WINDOW


def brute_locality(windows: np.ndarray, mask: np.ndarray):
    """Independent O(W^2) numpy implementation, mirroring the paper text."""
    spatial_sum = 0.0
    temporal_sum = 0.0
    for w, m in zip(windows, mask):
        if m == 0.0:
            continue
        # Spatial: min non-zero pairwise |distance|.
        best = None
        for i in range(len(w)):
            for j in range(i + 1, len(w)):
                d = abs(int(w[i]) - int(w[j]))
                if d > 0 and (best is None or d < best):
                    best = d
        spatial_sum += 0.0 if best is None else 1.0 / best
        # Temporal: per unique address with k >= 2, add 2^floor(log2 k).
        vals, counts = np.unique(np.asarray(w, dtype=np.int64), return_counts=True)
        for k in counts:
            if k >= 2:
                temporal_sum += float(2 ** int(np.floor(np.log2(k))))
    return spatial_sum, temporal_sum


def pad_windows(windows: np.ndarray):
    """Pad to a TILE multiple with masked-out windows."""
    n = windows.shape[0]
    n_pad = (-n) % TILE
    if n_pad:
        pad = np.zeros((n_pad, W), dtype=np.float64)
        windows = np.concatenate([windows, pad], axis=0)
    mask = np.concatenate([np.ones(n), np.zeros(n_pad)])
    return jnp.asarray(windows, dtype=jnp.float64), jnp.asarray(mask, dtype=jnp.float64)


addresses = st.integers(min_value=0, max_value=2**40)


@st.composite
def window_arrays(draw, max_windows=6):
    n = draw(st.integers(min_value=1, max_value=max_windows))
    kind = draw(st.sampled_from(["random", "sequential", "repeats", "strided"]))
    rng = np.random.default_rng(draw(st.integers(0, 2**32 - 1)))
    if kind == "random":
        w = rng.integers(0, 2**40, size=(n, W))
    elif kind == "sequential":
        start = draw(addresses)
        w = (start + np.arange(n * W)).reshape(n, W)
    elif kind == "repeats":
        base = rng.integers(0, 2**20, size=(n, 4))
        w = base[:, rng.integers(0, 4, size=W)]
    else:
        stride = draw(st.integers(1, 4096))
        start = draw(st.integers(0, 2**30))
        w = (start + stride * np.arange(n * W)).reshape(n, W)
    return w.astype(np.float64)


class TestLocalityKernel:
    def test_sequential_window_spatial_one(self):
        w = np.arange(TILE * W, dtype=np.float64).reshape(TILE, W)
        windows, mask = pad_windows(w)
        s, t = loc.locality_windows(windows, mask)
        assert float(s) == pytest.approx(TILE, rel=1e-12)
        assert float(t) == 0.0

    def test_single_address_temporal_full(self):
        w = np.full((TILE, W), 7.0)
        windows, mask = pad_windows(w)
        s, t = loc.locality_windows(windows, mask)
        assert float(s) == 0.0
        # k=32 -> 2^5 per window.
        assert float(t) == pytest.approx(32.0 * TILE, rel=1e-12)

    def test_mask_excludes_padding(self):
        w = np.arange(W, dtype=np.float64).reshape(1, W)
        windows, mask = pad_windows(w)
        s, _ = loc.locality_windows(windows, mask)
        assert float(s) == pytest.approx(1.0, rel=1e-12)

    @settings(max_examples=25, deadline=None)
    @given(window_arrays())
    def test_pallas_matches_ref(self, w):
        windows, mask = pad_windows(w)
        s_p, t_p = loc.locality_windows(windows, mask)
        s_r, t_r = ref.locality_windows_ref(windows, mask)
        np.testing.assert_allclose(float(s_p), float(s_r), rtol=1e-12)
        np.testing.assert_allclose(float(t_p), float(t_r), rtol=1e-12)

    @settings(max_examples=10, deadline=None)
    @given(window_arrays(max_windows=3))
    def test_ref_matches_brute_force(self, w):
        windows, mask = pad_windows(w)
        s_r, t_r = ref.locality_windows_ref(windows, mask)
        s_b, t_b = brute_locality(np.asarray(windows), np.asarray(mask))
        np.testing.assert_allclose(float(s_r), s_b, rtol=1e-12)
        np.testing.assert_allclose(float(t_r), t_b, rtol=1e-12)

    def test_large_address_precision(self):
        # Word addresses up to 2^40 must survive the f64 path exactly.
        base = float(2**40 - 64)
        w = (base + np.arange(W, dtype=np.float64)).reshape(1, W)
        windows, mask = pad_windows(w)
        s, t = loc.locality_windows(windows, mask)
        assert float(s) == pytest.approx(1.0, rel=1e-12)
        assert float(t) == 0.0


class TestKmeansKernel:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**32 - 1), st.integers(1, km.N_CENTROIDS))
    def test_assign_matches_ref(self, seed, k):
        rng = np.random.default_rng(seed)
        pts = jnp.asarray(rng.normal(size=(km.N_POINTS, km.N_FEATURES)), dtype=jnp.float32)
        cent = jnp.asarray(rng.normal(size=(k, km.N_FEATURES)), dtype=jnp.float32)
        a_p = km.kmeans_assign(pts, cent)
        a_r = ref.kmeans_assign_ref(pts, cent)
        np.testing.assert_array_equal(np.asarray(a_p), np.asarray(a_r))

    def test_step_matches_ref(self):
        rng = np.random.default_rng(3)
        pts = jnp.asarray(rng.normal(size=(km.N_POINTS, km.N_FEATURES)), dtype=jnp.float32)
        cent = jnp.asarray(rng.normal(size=(km.N_CENTROIDS, km.N_FEATURES)), dtype=jnp.float32)
        mask = jnp.asarray((np.arange(km.N_POINTS) < 44).astype(np.float32))
        a_p, c_p = km.kmeans_step(pts, cent, mask)
        a_r, c_r = ref.kmeans_update_ref(pts, cent, mask)
        np.testing.assert_array_equal(np.asarray(a_p), np.asarray(a_r))
        np.testing.assert_allclose(np.asarray(c_p), np.asarray(c_r), rtol=1e-6)

    def test_two_blobs_converge(self):
        rng = np.random.default_rng(9)
        a = rng.normal(size=(32, km.N_FEATURES)) * 0.05
        b = rng.normal(size=(32, km.N_FEATURES)) * 0.05 + 3.0
        pts = jnp.asarray(np.concatenate([a, b]), dtype=jnp.float32)
        mask = jnp.ones(64, dtype=jnp.float32)
        cent = jnp.asarray(rng.normal(size=(km.N_CENTROIDS, km.N_FEATURES)), dtype=jnp.float32)
        for _ in range(10):
            assign, cent = km.kmeans_step(pts, cent, mask)
        assign = np.asarray(assign)
        assert len(set(assign[:32])) == 1
        assert len(set(assign[32:])) == 1
        assert assign[0] != assign[32]


class TestModelShapes:
    def test_locality_chunk_shapes(self):
        w = jnp.zeros((model.CHUNK_WINDOWS, model.WINDOW), dtype=jnp.float64)
        m = jnp.zeros((model.CHUNK_WINDOWS,), dtype=jnp.float64)
        s, t, n = model.locality_chunk(w, m)
        assert s.shape == () and t.shape == () and n.shape == ()

    def test_kmeans_iteration_shapes(self):
        pts, cent, mask = (jnp.zeros(s.shape, s.dtype) for s in model.kmeans_example_args())
        a, c = model.kmeans_iteration(pts, cent, mask)
        assert a.shape == (model.KM_POINTS,)
        assert a.dtype == jnp.int32
        assert c.shape == (model.KM_CENTROIDS, model.KM_FEATURES)
