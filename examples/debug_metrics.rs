use damov::sim::{simulate, CoreModel, SystemConfig};
use damov::workloads::{registry, Scale};

fn main() {
    let code = std::env::args().nth(1).unwrap_or("PLYgemver".into());
    let cores: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(1);
    let f = registry::by_code(&code).expect("unknown code");
    let t = f.trace(cores, Scale(1.0));
    let total: usize = t.iter().map(Vec::len).sum();
    println!("{} cores={} accesses={}", code, cores, total);
    for cfg in [
        SystemConfig::host(cores, CoreModel::OutOfOrder),
        SystemConfig::host_prefetch(cores, CoreModel::OutOfOrder),
        SystemConfig::ndp(cores, CoreModel::OutOfOrder),
    ] {
        let r = simulate(&cfg, &t);
        println!(
            "{:8} perf={:9.1} ipc={:5.2} mb={:.2} mpki={:6.2} lfmr={:.3} ai={:5.1} amat={:6.1} parts={:?} fracs={:?} rho={:.2} dlat={:6.1} bw={:.1}GB/s",
            r.system, r.perf(), r.ipc, r.memory_bound, r.mpki, r.lfmr, r.ai, r.amat,
            r.amat_parts.map(|x| x.round()), r.level_fracs.map(|x| (x*100.0).round()),
            r.dram_rho, r.dram_loaded_lat, r.bw_bytes_s/1e9,
        );
    }
}
