//! Quickstart: simulate one workload on the three systems and print the
//! headline comparison — the 60-second tour of the library.
//!
//! Run: `cargo run --release --example quickstart`

use damov::sim::{simulate, CoreModel, SystemConfig, CORE_SWEEP};
use damov::workloads::{registry, Scale};

fn main() {
    // Pick STREAM Triad — the canonical DRAM-bandwidth-bound kernel
    // (class 1a) — and sweep it across the paper's three systems.
    let spec = registry::by_code("STRTriad").expect("suite function");
    println!(
        "workload: {} ({}, paper class {})\n",
        spec.id.code(),
        spec.id.suite,
        spec.paper_class.unwrap_or("?")
    );
    println!(
        "{:>6} {:>12} {:>12} {:>12} {:>10}",
        "cores", "host", "host+pf", "ndp", "ndp/host"
    );
    for &cores in CORE_SWEEP.iter() {
        let trace = spec.trace(cores, Scale::full());
        let host = simulate(&SystemConfig::host(cores, CoreModel::OutOfOrder), &trace);
        let pf = simulate(
            &SystemConfig::host_prefetch(cores, CoreModel::OutOfOrder),
            &trace,
        );
        let ndp = simulate(&SystemConfig::ndp(cores, CoreModel::OutOfOrder), &trace);
        println!(
            "{:>6} {:>12.1} {:>12.1} {:>12.1} {:>9.2}x",
            cores,
            host.perf(),
            pf.perf(),
            ndp.perf(),
            ndp.perf() / host.perf()
        );
    }
    println!(
        "\nExpected shape (paper §3.3.1): the host saturates its off-chip link at\n\
         ~64 cores while NDP keeps scaling on internal bandwidth (up to ~4x)."
    );

    // Every simulate() call above fed the telemetry registry; dump it.
    println!("\n--- telemetry snapshot ---");
    print!("{}", damov::util::telemetry::metrics::render_text());
}
