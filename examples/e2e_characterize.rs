//! End-to-end driver: the complete DAMOV methodology on a real (small)
//! workload suite, exercising every layer of the stack:
//!
//!   workload generators (L3) -> DAMOV-SIM replay + timing (L3)
//!   -> Step-2 locality via the AOT Pallas artifact on PJRT (L1/L2)
//!   -> Step-3 scalability sweep -> six-class classification
//!   -> headline per-class NDP-speedup table (Fig 18b shape)
//!
//! Run: `make artifacts && cargo run --release --example e2e_characterize`
//! (falls back to the Rust locality oracle if artifacts are missing).
//! Results recorded in EXPERIMENTS.md §End-to-end.

use damov::methodology::classify::{self, Class, Features};
use damov::methodology::locality;
use damov::methodology::step3::{profile_all, SweepOptions};
use damov::runtime::{artifact, Analytics};
use damov::sim::CoreModel;
use damov::util::pool::default_threads;
use damov::util::stats::geomean;
use damov::workloads::{registry, Scale};

fn main() {
    let t0 = std::time::Instant::now();
    let threads = default_threads();
    // Full scale by default: the bottleneck classes are defined against
    // the fixed Table-1 cache sizes, so shrinking working sets changes
    // class shapes (override with DAMOV_SCALE for quick smoke runs).
    let scale = Scale(
        std::env::var("DAMOV_SCALE")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(1.0),
    );
    // One representative per class keeps the e2e run laptop-fast while
    // still covering every bottleneck class.
    let codes = [
        "STRTriad", "LIGPrkEmd", // 1a
        "CHAHsti", // 1b
        "DRKRes",  // 1c
        "PLYGramSch", // 2a
        "PLYgemver",  // 2b
        "PLY3mm",  // 2c
        "RODNw",   // 2c
    ];
    let specs: Vec<_> = codes
        .iter()
        .map(|c| registry::by_code(c).expect("suite function"))
        .collect();

    // --- Step 1+3: simulate the sweep (parallel) ---
    println!("[1/3] simulating 3 systems x 5 core counts x {} functions...", specs.len());
    let profiles = profile_all(
        &specs,
        SweepOptions {
            scale,
            ..Default::default()
        },
        threads,
    );

    // --- Step 2: locality through the PJRT artifact when available ---
    let analytics = if artifact::artifacts_available() {
        match Analytics::load(&artifact::default_artifact_dir()) {
            Ok(a) => {
                println!("[2/3] locality via AOT Pallas artifact (PJRT CPU, platform loaded)");
                Some(a)
            }
            Err(e) => {
                println!("[2/3] artifact load failed ({e}); using Rust oracle");
                None
            }
        }
    } else {
        println!("[2/3] artifacts not built; using Rust oracle (run `make artifacts`)");
        None
    };

    // Default thresholds calibrated on this repo's representative suite
    // (the `damov validate` report derives them from data; the paper's
    // corpus yields 0.48 / 8.5 / 11.0 / 0.56 on its own scale).
    let thr = classify::Thresholds {
        temporal: 0.30,
        ai: 8.5,
        mpki: 45.0,
        lfmr: 0.56,
        slope_dec: -0.25,
        slope_inc: 0.25,
    };

    println!("[3/3] classification + headline table\n");
    println!(
        "{:12} {:>8} {:>8} {:>8} {:>8} {:>7} {:>6} {:>6} {:>9}",
        "function", "spatial", "temporal", "AI", "MPKI", "LFMR", "slope", "class", "paper"
    );
    let mut per_class: std::collections::BTreeMap<&'static str, Vec<f64>> = Default::default();
    let mut correct = 0usize;
    for (spec, p) in specs.iter().zip(&profiles) {
        let trace = spec.locality_trace(scale);
        let loc = match &analytics {
            Some(a) => {
                let m = a.locality(&trace).expect("pjrt locality");
                // Cross-check the artifact against the Rust oracle.
                let r = locality::locality(&trace);
                assert!(
                    (m.spatial - r.spatial).abs() < 1e-9
                        && (m.temporal - r.temporal).abs() < 1e-9,
                    "PJRT/Rust locality mismatch for {}",
                    p.code
                );
                m
            }
            None => locality::locality(&trace),
        };
        let mut feats = Features::of(p);
        feats.temporal = loc.temporal;
        let class = classify::classify(&feats, &thr);
        let expected = Class::parse(p.family_class).unwrap();
        if class == expected {
            correct += 1;
        }
        println!(
            "{:12} {:>8.3} {:>8.3} {:>8.2} {:>8.2} {:>7.3} {:>+6.2} {:>6} {:>9}",
            p.code,
            loc.spatial,
            loc.temporal,
            feats.ai,
            feats.mpki,
            feats.lfmr,
            feats.slope,
            class.label(),
            expected.label(),
        );
        let speeds: Vec<f64> = damov::sim::CORE_SWEEP
            .iter()
            .map(|&c| p.ndp_speedup(CoreModel::OutOfOrder, c))
            .filter(|s| s.is_finite())
            .collect();
        per_class.entry(expected.label()).or_default().extend(speeds);
    }

    println!("\nHeadline: mean NDP speedup per class (paper Fig 18b, OoO)");
    let paper = [
        ("1a", 1.59),
        ("1b", 1.22),
        ("1c", 0.96),
        ("2a", 1.04),
        ("2b", 0.94),
        ("2c", 0.56),
    ];
    for (class, paper_mean) in paper {
        if let Some(speeds) = per_class.get(class) {
            println!(
                "  class {class}: measured {:.2}x   (paper {paper_mean:.2}x)",
                geomean(speeds)
            );
        }
    }
    println!(
        "\nclassification: {correct}/{} correct; wall time {:.1?} on {threads} threads",
        specs.len(),
        t0.elapsed()
    );
    assert!(
        correct * 10 >= specs.len() * 7,
        "e2e classification accuracy below 70% — methodology regression"
    );
    println!("e2e OK");
}
