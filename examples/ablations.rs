//! Ablation study for the design choices DESIGN.md §3 calls out:
//!
//! * A1 — MSHR count (the MLP ceiling of the interval core model);
//! * A2 — prefetcher degree/streams (Table 1 uses 2/16);
//! * A3 — HMC row-buffer size (256 B default; what if 2 KiB DDR-style?);
//! * A4 — replay interleaving quantum (64-access default).
//!
//! Each ablation reruns one representative workload per affected class
//! and reports the headline metric it moves, so the sensitivity of the
//! reproduction to each modeling choice is explicit.
//!
//! Run: `cargo run --release --example ablations`

use damov::sim::{simulate, CoreModel, SystemConfig};
use damov::workloads::{registry, Scale};

fn main() {
    let scale = Scale(0.5);

    println!("A1: MSHR count vs class-1a NDP speedup (STRTriad, 16 cores)");
    let spec = registry::by_code("STRTriad").unwrap();
    for mshrs in [2u64, 4, 10, 32] {
        let mut host = SystemConfig::host(16, CoreModel::OutOfOrder);
        let mut ndp = SystemConfig::ndp(16, CoreModel::OutOfOrder);
        host.mshrs = mshrs;
        ndp.mshrs = mshrs;
        let t = spec.trace(16, scale);
        let h = simulate(&host, &t);
        let n = simulate(&ndp, &t);
        println!(
            "  mshrs={mshrs:>2}: host ipc {:5.2}  ndp/host {:.2}x",
            h.ipc,
            n.perf() / h.perf()
        );
    }

    println!("\nA2: prefetcher degree vs class-2c speedup over no-pf (PLY3mm, 4 cores)");
    let spec = registry::by_code("PLY3mm").unwrap();
    let t = spec.trace(4, scale);
    let base = simulate(&SystemConfig::host(4, CoreModel::OutOfOrder), &t);
    for (deg, streams) in [(1usize, 8usize), (2, 16), (4, 16), (8, 32)] {
        let mut cfg = SystemConfig::host_prefetch(4, CoreModel::OutOfOrder);
        cfg.pf_degree = deg;
        cfg.pf_streams = streams;
        let r = simulate(&cfg, &t);
        println!(
            "  degree={deg} streams={streams:>2}: speedup {:.3}x  accuracy {:.2}",
            r.perf() / base.perf(),
            r.pf_accuracy
        );
    }

    println!("\nA3: DRAM row-buffer size vs row-hit rate (STRTriad + CHAHsti, 16 cores)");
    for code in ["STRTriad", "CHAHsti"] {
        let spec = registry::by_code(code).unwrap();
        let t = spec.trace(16, scale);
        for row_bytes in [256usize, 1024, 2048] {
            let mut cfg = SystemConfig::host(16, CoreModel::OutOfOrder);
            cfg.dram.row_bytes = row_bytes;
            let r = simulate(&cfg, &t);
            println!(
                "  {code:10} row={row_bytes:>4}B: row-hit {:.2}  amat {:6.1}",
                r.row_hit_rate, r.amat
            );
        }
    }

    println!(
        "\nA4: the replay quantum is fixed at 64 accesses; its effect is the\n\
         interleaving granularity of shared-cache contention. Rerun the 2a\n\
         collapse with artificially serialized threads for comparison:"
    );
    let spec = registry::by_code("PLYGramSch").unwrap();
    let cfg = SystemConfig::host(64, CoreModel::OutOfOrder);
    let t = spec.trace(64, scale);
    let interleaved = simulate(&cfg, &t);
    // Serialized proxy: simulate each thread alone on a 1-core host and
    // take the max (no L3 contention).
    let solo_worst = t
        .iter()
        .map(|thread| {
            let one = SystemConfig::host(1, CoreModel::OutOfOrder);
            simulate(&one, &vec![thread.clone()]).lfmr
        })
        .fold(0.0f64, f64::max);
    println!(
        "  interleaved LFMR {:.3} vs contention-free worst-thread LFMR {:.3}\n\
         (the gap IS the cache-contention effect the 2a class measures)",
        interleaved.lfmr, solo_worst
    );
}
