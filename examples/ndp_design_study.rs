//! Scenario example: using DAMOV to drive an NDP design-space question —
//! "should my NDP use few big cores or many small ones, and does the
//! inter-vault network matter for my workload mix?" (case studies 1+3
//! turned into a reusable driver).
//!
//! Run: `cargo run --release --example ndp_design_study [codes...]`

use damov::sim::engine::{simulate_opt, SimOptions};
use damov::sim::{simulate, CoreModel, SystemConfig};
use damov::workloads::{registry, Scale};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let codes: Vec<String> = if args.is_empty() {
        ["STRTriad", "LIGPrkEmd", "CHAHsti", "PLYgemver"]
            .iter()
            .map(|s| s.to_string())
            .collect()
    } else {
        args
    };
    let scale = Scale(0.5);
    println!(
        "{:12} {:>12} {:>12} {:>12} {:>10} {:>10}",
        "function", "host 4xOoO", "ndp 6xOoO", "ndp 128xIO", "mesh cost", "imbalance"
    );
    for code in &codes {
        let Some(spec) = registry::by_code(code) else {
            eprintln!("unknown function {code}; see `damov list`");
            continue;
        };
        // Iso-area alternatives (case study 3).
        let host = simulate(&SystemConfig::host(4, CoreModel::OutOfOrder), &spec.trace(4, scale));
        let big = simulate(&SystemConfig::ndp(6, CoreModel::OutOfOrder), &spec.trace(6, scale));
        let many = simulate(
            &SystemConfig::ndp(128, CoreModel::InOrder),
            &spec.trace(128, scale),
        );
        // Inter-vault NoC sensitivity (case study 1) at 16 cores.
        let cfg16 = SystemConfig::ndp(16, CoreModel::OutOfOrder);
        let t16 = spec.trace(16, scale);
        let ideal = simulate(&cfg16, &t16);
        let mesh = simulate_opt(&cfg16, &t16, SimOptions { ndp_mesh: true });
        let mesh_cost = (ideal.perf() / mesh.perf() - 1.0) * 100.0;
        println!(
            "{:12} {:>12.1} {:>11.2}x {:>11.2}x {:>9.1}% {:>10.2}",
            code,
            host.perf(),
            big.perf() / host.perf(),
            many.perf() / host.perf(),
            mesh_cost,
            mesh.vault_imbalance,
        );
    }
    println!(
        "\nReading: bandwidth/latency-bound functions favor many small cores\n\
         (the paper's case study 3); the mesh column is the price of remote\n\
         vault traffic (case study 1) — high values argue for smarter data\n\
         placement before adding cores."
    );
}
