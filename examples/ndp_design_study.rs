//! Scenario example: using DAMOV to drive an NDP design-space question —
//! "should my NDP use few big cores or many small ones, does the
//! inter-vault network matter for my workload mix, and how much L1 does
//! an NDP core actually need?" (case studies 1+3 turned into a reusable
//! driver, plus a spec-builder L1 ablation).
//!
//! Run: `cargo run --release --example ndp_design_study [codes...]`

use damov::methodology::classify::{self, Features};
use damov::methodology::locality;
use damov::methodology::step3::{profile_function, SweepOptions};
use damov::sim::engine::{simulate_opt, SimOptions};
use damov::sim::{simulate, CoreModel, MemoryBackend, SystemConfig, SystemSpec};
use damov::workloads::{registry, FunctionSpec, Scale};

/// Three in-vault core designs differing only in L1 capacity, expressed
/// as custom [`SystemSpec`]s through the builder API — the same objects
/// `damov report --systems my.json` loads from JSON.
fn l1_ablation_specs() -> Vec<SystemSpec> {
    [16usize, 32, 64]
        .into_iter()
        .map(|kib| {
            SystemSpec::builder(&format!("ndp-l1-{kib}k"))
                .backend(MemoryBackend::DirectVault)
                .read_only_l1(true)
                .private_cache(kib << 10, 8, 4, 15.0, 33.0)
                .build()
                .expect("ablation spec must validate")
        })
        .collect()
}

/// Same calibrated thresholds `damov characterize` uses (§3.5.1).
fn thresholds() -> classify::Thresholds {
    classify::Thresholds {
        temporal: 0.30,
        ai: 8.5,
        mpki: 45.0,
        lfmr: 0.56,
        slope_dec: -0.25,
        slope_inc: 0.25,
    }
}

/// Sweep one function under one candidate spec and report the metrics
/// that drive the bottleneck classification.
fn ablate(spec: &FunctionSpec, sys: &SystemSpec, scale: Scale) -> (f64, f64, f64, &'static str) {
    let p = profile_function(
        spec,
        SweepOptions {
            systems: vec![sys.clone()],
            scale,
            ..Default::default()
        },
    );
    let loc = locality::locality(&spec.locality_trace(scale));
    let mut feats = Features::of(&p);
    feats.temporal = loc.temporal;
    let class = classify::classify(&feats, &thresholds());
    let perf = p
        .run(&sys.name, CoreModel::OutOfOrder, 256)
        .map(|r| r.result.perf())
        .unwrap_or(f64::NAN);
    (perf, p.mpki, p.lfmr_mean(), class.label())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let codes: Vec<String> = if args.is_empty() {
        ["STRTriad", "LIGPrkEmd", "CHAHsti", "PLYgemver"]
            .iter()
            .map(|s| s.to_string())
            .collect()
    } else {
        args
    };
    let scale = Scale(0.5);
    println!(
        "{:12} {:>12} {:>12} {:>12} {:>10} {:>10}",
        "function", "host 4xOoO", "ndp 6xOoO", "ndp 128xIO", "mesh cost", "imbalance"
    );
    for code in &codes {
        let Some(spec) = registry::by_code(code) else {
            eprintln!("unknown function {code}; see `damov list`");
            continue;
        };
        // Iso-area alternatives (case study 3).
        let host = simulate(&SystemConfig::host(4, CoreModel::OutOfOrder), &spec.trace(4, scale));
        let big = simulate(&SystemConfig::ndp(6, CoreModel::OutOfOrder), &spec.trace(6, scale));
        let many = simulate(
            &SystemConfig::ndp(128, CoreModel::InOrder),
            &spec.trace(128, scale),
        );
        // Inter-vault NoC sensitivity (case study 1) at 16 cores.
        let cfg16 = SystemConfig::ndp(16, CoreModel::OutOfOrder);
        let t16 = spec.trace(16, scale);
        let ideal = simulate(&cfg16, &t16);
        let mesh = simulate_opt(&cfg16, &t16, SimOptions { ndp_mesh: true });
        let mesh_cost = (ideal.perf() / mesh.perf() - 1.0) * 100.0;
        println!(
            "{:12} {:>12.1} {:>11.2}x {:>11.2}x {:>9.1}% {:>10.2}",
            code,
            host.perf(),
            big.perf() / host.perf(),
            many.perf() / host.perf(),
            mesh_cost,
            mesh.vault_imbalance,
        );
    }
    println!(
        "\nReading: bandwidth/latency-bound functions favor many small cores\n\
         (the paper's case study 3); the mesh column is the price of remote\n\
         vault traffic (case study 1) — high values argue for smarter data\n\
         placement before adding cores."
    );

    // --- L1 ablation: three NDP spec variants via the builder API. -----
    let variants = l1_ablation_specs();
    let ablation_scale = Scale(0.1);
    println!(
        "\nNDP L1 ablation (spec builder; perf = OoO @ 256 cores, scale {}):",
        ablation_scale.0
    );
    println!(
        "{:12} {:>12} {:>12} {:>8} {:>8} {:>6}",
        "function", "spec", "perf", "mpki", "lfmr", "class"
    );
    for code in &codes {
        let Some(spec) = registry::by_code(code) else {
            continue;
        };
        for sys in &variants {
            let (perf, mpki, lfmr, class) = ablate(&spec, sys, ablation_scale);
            println!(
                "{:12} {:>12} {:>12.1} {:>8.2} {:>8.3} {:>6}",
                code, sys.name, perf, mpki, lfmr, class
            );
        }
    }
    println!(
        "\nReading: if a function's class and LFMR barely move from 16k to\n\
         64k, its working set never fit anyway — spend the vault area on\n\
         cores, not cache. Class shifts (e.g. 1a -> 2b) mark functions\n\
         whose bottleneck an in-vault L1 can actually remove."
    );
}
