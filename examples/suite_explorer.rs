//! Scenario example: explore the DAMOV suite itself — print the Step-2
//! locality map of every representative function and how well the
//! architecture-independent view predicts the architecture-dependent
//! class (the paper's Fig 3 insight as a tool).
//!
//! Run: `cargo run --release --example suite_explorer`

use damov::methodology::locality;
use damov::util::table::bar;
use damov::workloads::{registry, Scale};

fn main() {
    let scale = Scale(0.25);
    println!(
        "{:12} {:5} {:>8} {:>9}  {:22} {:22}",
        "function", "class", "spatial", "temporal", "spatial", "temporal"
    );
    let mut reps = registry::representatives();
    reps.sort_by_key(|r| r.paper_class.unwrap_or("?"));
    for spec in &reps {
        let m = locality::locality(&spec.locality_trace(scale));
        println!(
            "{:12} {:5} {:>8.3} {:>9.3}  {:22} {:22}",
            spec.id.code(),
            spec.paper_class.unwrap_or("?"),
            m.spatial,
            m.temporal,
            bar(m.spatial, 22),
            bar(m.temporal, 22),
        );
    }
    println!(
        "\nReading (paper §3.2): class 1x functions sit low on temporal locality,\n\
         class 2x high — the architecture-independent signal that drives Step 2."
    );
}
